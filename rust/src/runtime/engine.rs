//! PJRT execution engine for the FVR-256 chunk-digest artifacts.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::artifact::{Manifest, VariantInfo};
use crate::hashes::fvr256::Geometry;

/// A compiled chunk-digest executable plus its geometry.
struct Compiled {
    geometry: Geometry,
    exe: xla::PjRtLoadedExecutable,
}

/// Executes AOT-compiled FVR-256 chunk digests through the PJRT CPU client.
///
/// Thread-safety: `PjRtClient` and `PjRtLoadedExecutable` are documented
/// thread-safe in PJRT (concurrent `Execute` calls are part of the API
/// contract); the `xla` crate wrapper is `!Send` only because it holds raw
/// pointers. We assert `Send + Sync` on that basis and execute WITHOUT a
/// lock — FIVER's whole point is that the sender-side and receiver-side
/// checksum threads run concurrently, and serializing them through a mutex
/// was measured to double end-to-end time (EXPERIMENTS.md §Perf). The
/// engine is cheap to clone (`Arc` inside) so all threads share one
/// compiled executable.
#[derive(Clone)]
pub struct XlaHashEngine {
    inner: Arc<Compiled>,
    name: String,
}

// SAFETY: the PJRT CPU client's compile/execute/transfer entry points are
// thread-safe per the PJRT API contract; no interior mutation happens on
// the Rust side after construction.
unsafe impl Send for XlaHashEngine {}
unsafe impl Sync for XlaHashEngine {}

impl XlaHashEngine {
    /// Compile the artifact for `variant` ("256k" | "1m" | "4m"). With
    /// `use_ref` the pure-jnp reference lowering is compiled instead of the
    /// Pallas-kernel lowering (for A/B testing).
    pub fn load(manifest: &Manifest, variant: &str, use_ref: bool) -> Result<XlaHashEngine> {
        let info = manifest.variant(variant)?;
        Self::load_variant(manifest, info, use_ref)
    }

    /// Load one compiled variant, given its manifest entry directly.
    pub fn load_variant(
        manifest: &Manifest,
        info: &VariantInfo,
        use_ref: bool,
    ) -> Result<XlaHashEngine> {
        let path = manifest.hlo_path(info, use_ref);
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let exe = Self::compile(&client, &path)?;
        Ok(XlaHashEngine {
            inner: Arc::new(Compiled { geometry: info.geometry, exe }),
            name: format!("{}{}", info.name, if use_ref { "-ref" } else { "" }),
        })
    }

    fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        // HLO *text* interchange: the 0.5.1 xla_extension rejects jax>=0.5
        // serialized protos (64-bit instruction ids); the text parser
        // reassigns ids. See /opt/xla-example/README.md.
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// The chunk geometry this engine was compiled for.
    pub fn geometry(&self) -> Geometry {
        self.inner.geometry
    }

    /// Name of the loaded variant.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute one chunk digest: `words` must be exactly `chunk_words()`
    /// LE-packed u32s (zero-padded); `true_len` is the pre-padding byte
    /// count; `chunk_index` the chunk's position in the stream.
    pub fn chunk_digest_words(
        &self,
        words: &[u32],
        true_len: u64,
        chunk_index: u64,
    ) -> Result<[u32; 8]> {
        anyhow::ensure!(
            words.len() == self.inner.geometry.chunk_words(),
            "expected {} words, got {}",
            self.inner.geometry.chunk_words(),
            words.len()
        );
        let chunk = xla::Literal::vec1(words);
        let len_lit = xla::Literal::vec1(&[true_len as u32]);
        let idx_lit = xla::Literal::vec1(&[chunk_index as u32]);
        let result = self
            .inner
            .exe
            .execute::<xla::Literal>(&[chunk, len_lit, idx_lit])
            .map_err(|e| anyhow::anyhow!("PJRT execute failed: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("device->host transfer failed: {e:?}"))?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("expected 1-tuple result: {e:?}"))?;
        let vec = out
            .to_vec::<u32>()
            .map_err(|e| anyhow::anyhow!("expected u32[8] digest: {e:?}"))?;
        anyhow::ensure!(vec.len() == 8, "digest length {} != 8", vec.len());
        let mut digest = [0u32; 8];
        digest.copy_from_slice(&vec);
        Ok(digest)
    }

    /// Digest a (possibly short) chunk of bytes: LE-pack + zero-pad + run.
    pub fn chunk_digest_bytes(&self, data: &[u8], chunk_index: u64) -> Result<[u32; 8]> {
        let geo = self.geometry();
        anyhow::ensure!(data.len() <= geo.chunk_bytes(), "chunk larger than geometry");
        let words = crate::hashes::fvr256::pack_words(geo, data);
        self.chunk_digest_words(&words, data.len() as u64, chunk_index)
    }
}
