//! Streaming FVR-256 hasher backed by the XLA/PJRT artifact.
//!
//! Chunk digests run on the compiled HLO module ([`XlaHashEngine`]); the
//! cross-chunk chaining (absorb + final length binding) runs natively and
//! is bit-exact with [`crate::hashes::fvr256::Fvr256`] — tests assert the
//! two produce identical digests, which is the end-to-end proof that the
//! Pallas kernel, the jnp reference, the python spec and the Rust port all
//! agree.

use crate::hashes::fvr256::{absorb8, IV, MAGIC_F, MAGIC_R};
use crate::hashes::Hasher;

use super::XlaHashEngine;

/// Streaming hasher over the PJRT executable. Construct per file (or
/// [`reset`](Hasher::reset) between files); clone the engine freely across
/// threads.
pub struct FvrHasher {
    engine: XlaHashEngine,
    buf: Vec<u8>,
    state: [u32; 8],
    chunk_index: u64,
    total: u64,
    /// Set if a PJRT execution failed; surfaced on finalize.
    error: Option<String>,
}

impl FvrHasher {
    /// A streaming hasher backed by `engine`.
    pub fn new(engine: XlaHashEngine) -> FvrHasher {
        let cap = engine.geometry().chunk_bytes();
        FvrHasher {
            engine,
            buf: Vec::with_capacity(cap),
            state: IV,
            chunk_index: 0,
            total: 0,
            error: None,
        }
    }

    fn absorb_chunk(&mut self, data: &[u8]) {
        if self.error.is_some() {
            return;
        }
        match self.engine.chunk_digest_bytes(data, self.chunk_index) {
            Ok(cd) => {
                self.state = absorb8(&self.state, &cd);
                self.chunk_index += 1;
            }
            Err(e) => self.error = Some(e.to_string()),
        }
    }

    /// Final digest as words; `Err` if any PJRT execution failed.
    pub fn digest_words(&mut self) -> anyhow::Result<[u32; 8]> {
        if !self.buf.is_empty() {
            let tail = std::mem::take(&mut self.buf);
            self.absorb_chunk(&tail);
        }
        if let Some(e) = &self.error {
            anyhow::bail!("XLA hash execution failed: {e}");
        }
        let meta = [
            self.total as u32,
            (self.total >> 32) as u32,
            self.chunk_index as u32,
            MAGIC_F,
            MAGIC_R,
            0,
            0,
            0,
        ];
        Ok(absorb8(&self.state, &meta))
    }
}

impl Hasher for FvrHasher {
    fn update(&mut self, mut data: &[u8]) {
        self.total += data.len() as u64;
        let cb = self.engine.geometry().chunk_bytes();
        if !self.buf.is_empty() {
            let need = cb - self.buf.len();
            let take = need.min(data.len());
            self.buf.extend_from_slice(&data[..take]);
            data = &data[take..];
            if self.buf.len() == cb {
                let buf = std::mem::take(&mut self.buf);
                self.absorb_chunk(&buf);
                self.buf = buf;
                self.buf.clear();
            }
        }
        while data.len() >= cb {
            let (chunk, rest) = data.split_at(cb);
            self.absorb_chunk(chunk);
            data = rest;
        }
        self.buf.extend_from_slice(data);
    }

    fn finalize(&mut self) -> Vec<u8> {
        // Hasher's infallible interface: a PJRT failure yields an
        // all-zero digest, which can never match a healthy peer digest,
        // so verification fails closed. digest_words() exposes the error.
        match self.digest_words() {
            Ok(words) => words.iter().flat_map(|w| w.to_be_bytes()).collect(),
            Err(_) => vec![0u8; 32],
        }
    }

    fn digest_len(&self) -> usize {
        32
    }

    fn reset(&mut self) {
        self.buf.clear();
        self.state = IV;
        self.chunk_index = 0;
        self.total = 0;
        self.error = None;
    }
}
