//! `repro-experiments` — regenerate every table and figure of the paper's
//! evaluation (§IV) from the simulated testbeds.
//!
//! ```text
//! repro-experiments all          # everything, paper order
//! repro-experiments fig5 fig7    # specific figures
//! repro-experiments list         # available experiment names
//! ```

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "list" {
        eprintln!("usage: repro-experiments <name...|all|list>");
        eprintln!("experiments: {}", fiver::experiments::ALL.join(", "));
        std::process::exit(if args.is_empty() { 2 } else { 0 });
    }
    for name in &args {
        match fiver::experiments::run_by_name(name) {
            Some(out) => println!("{out}\n"),
            None => {
                eprintln!(
                    "unknown experiment `{name}`; try: {}",
                    fiver::experiments::ALL.join(", ")
                );
                std::process::exit(2);
            }
        }
    }
}
