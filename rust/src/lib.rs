//! # FIVER — Fast End-to-End Integrity Verification for High-Speed File Transfers
//!
//! Reproduction of Arslan & Alhussen (2018). The paper's contribution is a
//! *coordination* scheme: run the network transfer and the checksum
//! computation of the **same file** concurrently, sharing one file read
//! between them through a fixed-size synchronized queue, so end-to-end
//! integrity verification costs <10% instead of the ~60% imposed by
//! sequential / file-level / block-level pipelining approaches.
//!
//! The crate is organised in the three-layer architecture described in
//! `DESIGN.md`:
//!
//! * **Layer 3 (this crate)** — the coordinator: [`coordinator`] implements
//!   FIVER, FIVER-Hybrid and the three baseline algorithms over real sockets
//!   and threads, scaled out by a **parallel transfer engine** — a
//!   work-stealing file scheduler drives N concurrent sessions
//!   (`--concurrency`), each optionally striping its data over P sockets
//!   (`--parallel`), all feeding one shared hash worker pool per endpoint
//!   ([`coordinator::scheduler`], [`coordinator::pool`]; small files
//!   aggregate into batched work items so control exchanges amortize).
//!   The byte-moving layer is a **zero-copy data plane**
//!   ([`coordinator::bufpool`]): refcounted sliceable buffers recycled
//!   through a bounded (adaptively growing, optionally aligned) pool,
//!   vectored (`writev`) frame writes, and length-prefixed reads decoded
//!   straight into pooled buffers, so the steady state performs no
//!   payload allocation or copy per buffer cycle (DESIGN.md "Data plane
//!   & buffer ownership"). Storage access rides **pluggable I/O
//!   backends** ([`storage`], `--io-backend`): buffered pread/pwrite,
//!   mmap (zero-copy `SharedBuf` views of the file mapping, msync-backed
//!   durability), or O_DIRECT-style aligned I/O with graceful fallback —
//!   selectable per endpoint, modeled per backend in the sim, and gated
//!   by a cross-backend conformance suite (DESIGN.md "Storage I/O
//!   backends").
//!   Transfers are **crash-recoverable** ([`coordinator::journal`]): both
//!   endpoints checkpoint per-file leaf digests with crash-consistent
//!   writes, and a restarted pair negotiates per-file restart offsets —
//!   the delivered prefix verifies by Merkle-root comparison without
//!   re-reading a byte, and only the unfinished tail re-enters the
//!   scheduler (`--journal-dir` / `--resume`; gated by the
//!   crash-injection harness in `rust/tests/crash_recovery.rs`).
//!   Re-runs of a mostly-unchanged dataset go **incremental**
//!   ([`coordinator::delta`], `--delta`): the receiver offers per-leaf
//!   (rolling-weak, strong) signatures of the data it already holds —
//!   served from its name-keyed journal when one matches, else hashed
//!   from storage — the sender scans its source with an rsync-style
//!   rolling window and ships only unmatched byte ranges, and the
//!   receiver splices matched leaves out of its own old copy, then
//!   re-hashes the reconstructed file so the Merkle backstop verifies
//!   it end to end (DESIGN.md "Delta sync & journal v2").
//!   [`sim`] re-runs the same scheduling policies — including the engine,
//!   via [`sim::algorithms::run_concurrent`] — inside a discrete-event
//!   testbed model so the paper's 165 GB / 100 Gbps experiments (and
//!   concurrency sweeps beyond them) reproduce on a laptop.
//! * **Layer 3½ — Merkle verification** ([`merkle`]): a streaming digest
//!   tree grown over the same shared-queue bytes FIVER already hashes
//!   (zero extra file I/O). The `FiverMerkle` policy exchanges the O(1)
//!   root instead of per-chunk digests; on a mismatch the sender
//!   binary-searches the tree with node-range queries — O(log n) control
//!   round trips, O(k log n) digest bytes for k corrupted leaves — and
//!   re-reads/re-sends only the corrupted leaf ranges (O(k · leaf_size)
//!   repair bytes vs FIVER-Chunk's O(k · block_size) and plain FIVER's
//!   O(file)). Both real mode and the sim implement the same policy, so
//!   Table III replays at 100 Gbps scale with repair-cost telemetry
//!   (`repair_rounds`, `bytes_reread`, `verify_rtts`).
//! * **Observability plane** ([`obs`]) — always-on, allocation-free-in-
//!   steady-state tracing threaded through every layer above: per-stage
//!   spans (`read`/`hash`/`queue_wait`/`send`/`recv`/`write`/`verify`/
//!   `journal`/`repair`) recorded into pre-allocated per-worker ring
//!   buffers, sharded log2 latency + queue-depth histograms merged into
//!   p50/p95/p99 report fields, per-stage busy-time **bottleneck
//!   attribution** (`hash-bound` / `read-bound` / `write-bound` /
//!   `net-bound`, mirrored by the sim so labels are checkable against
//!   reality), Chrome/Perfetto `trace_event` export (`--trace-out`),
//!   merged-histogram JSON (`--metrics-json`) and a live throughput +
//!   pool-occupancy line (`--progress`). Enabled by `FIVER_TRACE=1` or
//!   any of those flags; the `alloc_regression.rs` gate runs tracing-on
//!   (DESIGN.md "Observability & tracing").
//! * **Layer 2/1 (build-time Python)** — the FVR-256 digest pipeline
//!   (JAX graph + Pallas block-hash kernel), AOT-lowered to HLO text which
//!   [`runtime`] loads and executes through the XLA PJRT CPU client.
//!   Python never runs on the transfer path.
//!
//! Substrates built in-tree (offline environment, and per the reproduction
//! mandate): from-scratch MD5/SHA-1/SHA-256 [`hashes`], an LRU page-cache
//! model [`cache`], a TCP throughput model with slow-start idle reset
//! [`net`], a discrete-event engine [`sim`], dataset generators
//! [`workload`], fault injection [`faults`], and a minimal JSON parser
//! [`util::json`] for the artifact manifest.

#![warn(missing_docs)]

/// Fluid-sim page-cache model with per-extent hit/miss accounting.
pub mod cache;
/// Testbed specifications and tunable algorithm parameters.
pub mod config;
/// Real transfer engine: sessions, wire protocol, verification, repair.
pub mod coordinator;
/// Drivers that regenerate the paper's tables and figures.
pub mod experiments;
/// Deterministic fault and crash injection plans.
pub mod faults;
/// From-scratch MD5/SHA-1/SHA-256 and the FVR-256 digest.
pub mod hashes;
/// Streaming Merkle digest tree over fixed-size leaves.
pub mod merkle;
/// Run summaries, hit-ratio traces and the Eq. 1 overhead model.
pub mod metrics;
/// TCP throughput envelope (slow start, steady state) for the sim.
pub mod net;
/// Allocation-free tracing and metrics plane.
pub mod obs;
/// XLA/PJRT runtime hosting the AOT-compiled FVR-256 pipeline.
pub mod runtime;
/// Fluid-flow discrete-event simulator of the testbeds.
pub mod sim;
/// Pluggable storage I/O backends (buffered, mmap, direct, in-memory).
pub mod storage;
/// Dependency-free helpers: CLI, JSON, hex, RNG, tables, temp dirs.
pub mod util;
/// Dataset generators describing the files a run transfers.
pub mod workload;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
