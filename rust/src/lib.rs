//! # FIVER — Fast End-to-End Integrity Verification for High-Speed File Transfers
//!
//! Reproduction of Arslan & Alhussen (2018). The paper's contribution is a
//! *coordination* scheme: run the network transfer and the checksum
//! computation of the **same file** concurrently, sharing one file read
//! between them through a fixed-size synchronized queue, so end-to-end
//! integrity verification costs <10% instead of the ~60% imposed by
//! sequential / file-level / block-level pipelining approaches.
//!
//! The crate is organised in the three-layer architecture described in
//! `DESIGN.md`:
//!
//! * **Layer 3 (this crate)** — the coordinator: [`coordinator`] implements
//!   FIVER, FIVER-Hybrid and the three baseline algorithms over real sockets
//!   and threads; [`sim`] re-runs the same scheduling policies inside a
//!   discrete-event testbed model so the paper's 165 GB / 100 Gbps
//!   experiments reproduce on a laptop.
//! * **Layer 2/1 (build-time Python)** — the FVR-256 digest pipeline
//!   (JAX graph + Pallas block-hash kernel), AOT-lowered to HLO text which
//!   [`runtime`] loads and executes through the XLA PJRT CPU client.
//!   Python never runs on the transfer path.
//!
//! Substrates built in-tree (offline environment, and per the reproduction
//! mandate): from-scratch MD5/SHA-1/SHA-256 [`hashes`], an LRU page-cache
//! model [`cache`], a TCP throughput model with slow-start idle reset
//! [`net`], a discrete-event engine [`sim`], dataset generators
//! [`workload`], fault injection [`faults`], and a minimal JSON parser
//! [`util::json`] for the artifact manifest.

pub mod cache;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod hashes;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod util;
pub mod workload;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
