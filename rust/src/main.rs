//! `fiver` — CLI for the FIVER integrity-verified transfer system.
//!
//! Subcommands:
//!
//! * `serve --data <addr> --ctrl <addr> --dir <path> [--alg A] [--hash H]`
//!   — run a receiver endpoint, serving one session per invocation.
//! * `send --data <addr> --ctrl <addr> --dir <path> [--alg A] [--hash H]
//!   <file...>` — transfer files (paths relative to `--dir`) to a receiver.
//! * `local --alg A --files N --size BYTES [--hash H] [--faults K]`
//!   — loopback demo: generate a dataset, transfer it through 127.0.0.1,
//!   verify, report throughput/overhead inputs.
//! * `hash --hash H <path...>` — checksum files (XLA path with
//!   `--hash fvr256-xla`).
//! * `experiment <name>` — alias for the repro-experiments binary.
//!
//! `--verify-tree` selects FIVER-Merkle (streaming digest-tree
//! verification with O(log n) corruption localization); `--leaf-size N`
//! sets its repair granularity (default 64 KiB). Both endpoints must
//! agree on the algorithm and leaf size.
//!
//! Tiered hashing (see `fiver::hashes` and DESIGN.md §Tiered hashing):
//!
//! * `--hash-tier fast|cryptographic|tiered` — which hash family digests
//!   what. `cryptographic` (default) uses the `--hash` algorithm
//!   everywhere, as before. `tiered` computes leaf, unit and journal
//!   digests with xxHash3-128 (~an order of magnitude faster than SHA)
//!   while Merkle interior nodes and roots keep the cryptographic
//!   `--hash` algorithm — transfers stop being hash-bound, yet every
//!   exchanged root stays a cryptographic digest over the leaf tree
//!   (single-leaf files fold once so even they anchor cryptographically).
//!   `fast` uses xxHash3-128 for everything (integrity against line
//!   errors only — no adversarial protection). Both endpoints must agree,
//!   like `--leaf-size`; journals written under another tier decline
//!   (re-journal) instead of erroring. The `FIVER_HASH_TIER` environment
//!   variable sets the default.
//!
//! Data-plane knobs (zero-copy buffer pool; see
//! `fiver::coordinator::bufpool`):
//!
//! * `--buffer-size N` (alias `--buf-size`) — I/O buffer granularity; one
//!   pooled buffer per read, shared by refcount between socket and hash
//!   queue.
//! * `--pool-buffers N` — buffers in the endpoint's pool (default: auto,
//!   sized so a full checksum queue per session plus in-flight slack
//!   never exhausts it).
//! * `--pool-max-buffers N` — adaptive-growth ceiling: a sustainedly
//!   exhausted pool grows up to this many buffers instead of permanently
//!   degrading to allocate-per-buffer (default: twice the pool size;
//!   grow events surface in the `data plane:` line).
//! * `--io-backend buffered|mmap|direct|uring|auto` — storage I/O engine
//!   (see `fiver::storage`): `buffered` is positioned pread/pwrite
//!   through the page cache (default); `mmap` serves zero-copy reads out
//!   of a file mapping and writes through `MAP_SHARED` stores with
//!   msync-backed durability; `direct` is O_DIRECT-style aligned I/O
//!   bypassing the page cache, falling back to buffered wherever the
//!   filesystem or the operation's alignment rules it out; `uring`
//!   batches reads and writes through an io_uring submission queue with
//!   the endpoint's pooled buffers registered for fixed-buffer I/O,
//!   falling back to buffered when the kernel refuses the ring; `auto`
//!   picks per file by size — files at or above `--direct-threshold`
//!   take the uring (or, ringless, the direct) engine, smaller files
//!   stay buffered. The `FIVER_IO_BACKEND` environment variable sets the
//!   default. Endpoints may choose their backends independently (the
//!   selection is local to each side's storage). The active backend and
//!   its sync count are reported on the `data plane:` line so overhead
//!   attributes to storage vs hash vs network.
//! * `--direct-threshold BYTES` — `auto` backend's size cutoff between
//!   the buffered engine and the batched/bypass engines (default
//!   256 MiB).
//!
//! Parallel engine knobs (serve/send/local; both endpoints must agree on
//! `--concurrency` and `--parallel`):
//!
//! * `--concurrency N` — N concurrent sessions fed by a work-stealing
//!   file scheduler (GridFTP-style concurrency).
//! * `--parallel P` — stripe each file's data over P sockets per session
//!   (GridFTP-style parallelism).
//! * `--hash-workers W` — shared hash pool size (default max(N, 2)).
//! * `--batch-threshold B` / `--batch-bytes T` — files under B bytes
//!   aggregate into work items of ~T bytes so small-file control
//!   round-trips amortize.
//!
//! Adaptive concurrency control (see `fiver::coordinator::control`;
//! forces the engine path and turns the tracing plane on — the
//! controller samples its live counters):
//!
//! * `--adaptive` — run the AIMD feedback controller: every
//!   `--control-interval` it labels the window hash-/read-/write-/
//!   net-bound from the live per-stage busy counters and moves the hash
//!   pool (grow by one on a sustained hash bottleneck, halve when the
//!   pool overshoots) and the per-file stripe count (probe-halve on a
//!   saturated wire, restore on a >10% throughput regression). Every
//!   decision lands in the report's `adaptive control:` trail.
//! * `--control-interval MS` — sample-window length (default 200).
//! * `--max-parallel P` — stripe-count ceiling; data lanes are
//!   provisioned up front to max(P, `--parallel`) (default 8).
//! * `--max-hash-workers W` — hash-pool growth ceiling (default 8).
//!
//! Crash recovery (see `fiver::coordinator::journal`):
//!
//! * `--journal-dir PATH` — checkpoint journal for this endpoint (each
//!   endpoint needs its own directory; `local` runs both endpoints, so it
//!   splits the path into `PATH/snd` and `PATH/rcv` automatically). Leaf
//!   digests of every file's delivered prefix are recorded with
//!   crash-consistent writes.
//! * `--resume` — negotiate per-file restart offsets from the journals at
//!   session start and re-send only the unfinished tails (both endpoints
//!   must pass it; forces the engine path).
//!
//! Incremental transfers (see `fiver::coordinator::delta`):
//!
//! * `--delta` — rsync-style delta sync (forces the engine path): a
//!   handshake fetches per-leaf signatures of the receiver's existing
//!   files (free when the receiver has `--journal-dir`, otherwise hashed
//!   on demand), the sender scans its source with a rolling checksum, and
//!   only changed leaf ranges ship; unchanged leaves are copied from the
//!   receiver's own data and the result is re-verified end-to-end. The
//!   report's `delta:` line shows the bytes that never crossed the wire.
//! * `local` only: `--crash-after BYTES` — kill the engine mid-transfer
//!   after ~BYTES streamed, then restart it against the journals and
//!   report what the resume saved (a self-contained recovery demo).
//!
//! Observability (see `fiver::obs`; any of these — or `FIVER_TRACE=1` —
//! turns the allocation-free tracing plane on, and per-stage
//! p50/p95/p99 latencies plus a bottleneck label join the report):
//!
//! * `--trace-out FILE` — write the per-stage span timeline as
//!   Chrome/Perfetto `trace_event` JSON (one track per session / hash
//!   worker; open at <https://ui.perfetto.dev>).
//! * `--metrics-json FILE` — dump the merged per-stage log2 latency and
//!   queue-depth histograms (sparse `[bucket, count]` pairs) plus the
//!   bottleneck attribution as JSON.
//! * `--progress` — render a live per-second throughput sparkline and
//!   buffer-pool occupancy line to stderr while the transfer runs.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use fiver::coordinator::scheduler::EngineConfig;
use fiver::coordinator::session::{
    connect_and_send, connect_and_send_engine, run_local_transfer, run_parallel_local_transfer,
    run_recoverable_local_transfer, ReceiverEndpoint,
};
use fiver::coordinator::{native_factory, xla_factory, HasherFactory, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::HashAlgorithm;
use fiver::storage::{FsStorage, Storage};
use fiver::util::cli::Args;
use fiver::util::fmt;
use fiver::workload::Dataset;

fn hasher_factory(name: &str) -> Result<HasherFactory> {
    if name.eq_ignore_ascii_case("fvr256-xla") {
        let dir = fiver::runtime::find_artifacts_dir()?;
        let manifest = fiver::runtime::Manifest::load(&dir)?;
        let engine = fiver::runtime::XlaHashEngine::load(&manifest, "1m", false)?;
        return Ok(xla_factory(engine));
    }
    let alg = HashAlgorithm::parse(name).with_context(|| {
        format!("unknown hash `{name}` ({}|fvr256-xla)", HashAlgorithm::names_joined())
    })?;
    Ok(native_factory(alg))
}

fn session_config(args: &Args) -> Result<SessionConfig> {
    // `--verify-tree` is shorthand for the Merkle policy; `--alg` wins if
    // both are given explicitly.
    let default_alg = if args.flag("verify-tree") { "fiver-merkle" } else { "fiver" };
    let alg = RealAlgorithm::parse(args.opt_or("alg", default_alg)).with_context(|| {
        let names: Vec<&str> = RealAlgorithm::ALL.iter().map(|a| a.name()).collect();
        format!("unknown --alg ({})", names.join("|"))
    })?;
    let mut cfg = SessionConfig::new(alg, hasher_factory(args.opt_or("hash", "fvr256"))?);
    // `--buffer-size` is the documented data-plane knob; `--buf-size` is
    // kept as its long-standing alias.
    cfg.buf_size =
        args.opt_u64("buffer-size", args.opt_u64("buf-size", cfg.buf_size as u64)) as usize;
    cfg.block_size = args.opt_u64("block-size", cfg.block_size);
    cfg.queue_capacity = args.opt_u64("queue-capacity", cfg.queue_capacity as u64) as usize;
    cfg.hybrid_threshold = args.opt_u64("hybrid-threshold", cfg.hybrid_threshold);
    cfg.leaf_size = args.opt_u64("leaf-size", cfg.leaf_size);
    cfg.pool_buffers = args.opt_u64("pool-buffers", 0) as usize;
    cfg.pool_max_buffers = args.opt_u64("pool-max-buffers", 0) as usize;
    cfg.io_backend = match args.opt("io-backend") {
        Some(s) => fiver::storage::IoBackend::parse(s).with_context(|| {
            let names: Vec<&str> =
                fiver::storage::IoBackend::ALL.iter().map(|b| b.name()).collect();
            format!("unknown --io-backend ({}|auto)", names.join("|"))
        })?,
        None => fiver::storage::IoBackend::from_env(),
    };
    cfg.direct_threshold = args.opt_u64("direct-threshold", cfg.direct_threshold);
    cfg.hash_tier = match args.opt("hash-tier") {
        Some(s) => fiver::hashes::HashTier::parse(s).with_context(|| {
            format!("unknown --hash-tier ({})", fiver::hashes::HashTier::names_joined())
        })?,
        None => fiver::hashes::HashTier::from_env(),
    };
    cfg.journal_dir = args.opt("journal-dir").map(|d| Path::new(d).to_path_buf());
    cfg.resume = args.flag("resume");
    cfg.delta = args.flag("delta");
    // `|=`: FIVER_ADAPTIVE=1 (via ControlConfig::from_env) stays on
    // without the flag — the CI lever for whole-suite adaptive runs.
    cfg.control.adaptive |= args.flag("adaptive");
    cfg.control.interval_ms = args.opt_u64("control-interval", cfg.control.interval_ms).max(1);
    cfg.control.max_parallel =
        (args.opt_u64("max-parallel", cfg.control.max_parallel as u64).max(1)) as usize;
    cfg.control.max_hash_workers =
        (args.opt_u64("max-hash-workers", cfg.control.max_hash_workers as u64).max(1)) as usize;
    // Any observability flag turns the tracing plane on (FIVER_TRACE=1
    // already did via SessionConfig::new). `--adaptive` needs it too:
    // the controller's signal is the recorder's live busy counters.
    if !cfg.obs.is_enabled()
        && (args.opt("trace-out").is_some()
            || args.opt("metrics-json").is_some()
            || args.flag("progress")
            || cfg.control.adaptive)
    {
        cfg.obs = fiver::obs::Recorder::enabled();
    }
    anyhow::ensure!(cfg.leaf_size > 0, "--leaf-size must be positive");
    anyhow::ensure!(cfg.buf_size > 0, "--buffer-size must be positive");
    anyhow::ensure!(
        !cfg.resume || cfg.journal_dir.is_some(),
        "--resume needs --journal-dir (the checkpoint to resume from)"
    );
    Ok(cfg)
}

/// Parallel-engine options (defaults are the classic single-session run).
fn engine_config(args: &Args) -> EngineConfig {
    let defaults = EngineConfig::default();
    EngineConfig {
        concurrency: args.opt_u64("concurrency", 1).max(1) as usize,
        parallel: args.opt_u64("parallel", 1).max(1) as usize,
        hash_workers: args.opt_u64("hash-workers", 0) as usize,
        batch_threshold: args.opt_u64("batch-threshold", defaults.batch_threshold),
        batch_bytes: args.opt_u64("batch-bytes", defaults.batch_bytes),
    }
}

/// Does this invocation use the parallel engine (vs the classic
/// single-session protocol without the Hello handshake)? `--resume` and
/// `--delta` force it (both handshakes ride the engine's Hello routing),
/// and so does `--adaptive` (the controller actuates the engine's shared
/// hash pool and per-session stripe lanes).
fn uses_engine(eng: &EngineConfig, cfg: &SessionConfig) -> bool {
    eng.concurrency > 1 || eng.parallel > 1 || cfg.resume || cfg.delta || cfg.control.adaptive
}

/// Engine-only tuning knobs do nothing on the classic path; warn instead
/// of silently measuring a different configuration than requested. For
/// `local` (where this process controls both endpoints) any engine flag
/// promotes the run to the engine instead.
fn engine_only_flags_given(args: &Args) -> bool {
    ["hash-workers", "batch-threshold", "batch-bytes"]
        .iter()
        .any(|opt| args.opt(opt).is_some())
}

fn warn_unused_engine_flags(args: &Args) {
    for opt in ["hash-workers", "batch-threshold", "batch-bytes"] {
        if args.opt(opt).is_some() {
            eprintln!("warning: --{opt} has no effect without --concurrency/--parallel > 1");
        }
    }
}

/// Start the live `--progress` line when asked (the recorder was already
/// enabled by `session_config` in that case).
fn start_progress(args: &Args, cfg: &SessionConfig) -> Option<fiver::obs::Progress> {
    if args.flag("progress") {
        Some(fiver::obs::Progress::start(cfg.obs.clone()))
    } else {
        None
    }
}

/// Stop the progress line and write the `--trace-out` / `--metrics-json`
/// exports after a run.
fn finish_obs(
    args: &Args,
    cfg: &SessionConfig,
    progress: Option<fiver::obs::Progress>,
) -> Result<()> {
    if let Some(p) = progress {
        p.finish();
    }
    if let Some(path) = args.opt("trace-out") {
        cfg.obs
            .write_chrome_trace_to(Path::new(path))
            .with_context(|| format!("writing trace to {path}"))?;
        eprintln!("trace written: {path}");
    }
    if let Some(path) = args.opt("metrics-json") {
        std::fs::write(path, cfg.obs.metrics_json())
            .with_context(|| format!("writing metrics to {path}"))?;
        eprintln!("metrics written: {path}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&[
        "data", "ctrl", "dir", "alg", "hash", "hash-tier", "buf-size", "buffer-size", "block-size",
        "queue-capacity", "hybrid-threshold", "leaf-size", "pool-buffers", "pool-max-buffers",
        "io-backend", "direct-threshold", "files", "size", "faults", "seed", "concurrency",
        "parallel", "hash-workers", "batch-threshold", "batch-bytes", "journal-dir", "crash-after",
        "trace-out", "metrics-json", "control-interval", "max-parallel", "max-hash-workers",
    ]);
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        eprintln!("usage: fiver <serve|send|local|hash|experiment> [options]");
        std::process::exit(2);
    };
    match cmd {
        "serve" => serve(&args),
        "send" => send(&args),
        "local" => local(&args),
        "hash" => hash_cmd(&args),
        "experiment" => {
            let name = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            match fiver::experiments::run_by_name(name) {
                Some(out) => {
                    println!("{out}");
                    Ok(())
                }
                None => bail!(
                    "unknown experiment `{name}` (try: {})",
                    fiver::experiments::ALL.join(", ")
                ),
            }
        }
        other => bail!("unknown subcommand `{other}`"),
    }
}

fn serve(args: &Args) -> Result<()> {
    let cfg = session_config(args)?;
    let eng = engine_config(args);
    let dir = args.opt("dir").context("--dir required")?;
    let storage: Arc<dyn Storage> = Arc::new(
        FsStorage::with_backend(Path::new(dir), cfg.io_backend)?
            .with_threshold(cfg.direct_threshold)
            .with_recorder(cfg.obs.clone()),
    );
    let endpoint = ReceiverEndpoint::bind(
        args.opt_or("data", "0.0.0.0:7001"),
        args.opt_or("ctrl", "0.0.0.0:7002"),
    )?;
    let (d, c) = endpoint.addrs()?;
    eprintln!(
        "fiver receiver: data={d} ctrl={c} alg={} concurrency={} parallel={}",
        cfg.algorithm.name(),
        eng.concurrency,
        eng.parallel,
    );
    let report = if uses_engine(&eng, &cfg) {
        let mut total = fiver::coordinator::receiver::ReceiverReport::default();
        for (i, r) in endpoint.serve_engine(storage, &cfg, &eng)?.iter().enumerate() {
            println!(
                "session {i}: {} files / {} ({} units verified, {} failures)",
                r.files_received,
                fmt::bytes(r.bytes_received),
                r.units_verified,
                r.units_failed,
            );
            total.merge(r);
        }
        total
    } else {
        warn_unused_engine_flags(args);
        endpoint.serve_one(storage, &cfg)?
    };
    println!(
        "received {} files / {} ({} units verified, {} failures, {} repaired)",
        report.files_received,
        fmt::bytes(report.bytes_received),
        report.units_verified,
        report.units_failed,
        fmt::bytes(report.bytes_repaired),
    );
    if report.direct_fallbacks > 0 {
        println!("data plane: {} direct-I/O fallbacks", report.direct_fallbacks);
    }
    if report.uring_fallbacks > 0 || report.storage_hints > 0 {
        println!(
            "data plane: {} uring fallbacks, {} storage hints issued",
            report.uring_fallbacks, report.storage_hints,
        );
    }
    finish_obs(args, &cfg, None)
}

fn send(args: &Args) -> Result<()> {
    let cfg = session_config(args)?;
    let eng = engine_config(args);
    let dir = args.opt("dir").context("--dir required")?;
    let storage: Arc<dyn Storage> = Arc::new(
        FsStorage::with_backend(Path::new(dir), cfg.io_backend)?
            .with_threshold(cfg.direct_threshold)
            .with_recorder(cfg.obs.clone()),
    );
    let files: Vec<String> = args.positional[1..].to_vec();
    anyhow::ensure!(!files.is_empty(), "no files given");
    let data_addr = args.opt_or("data", "127.0.0.1:7001");
    let ctrl_addr = args.opt_or("ctrl", "127.0.0.1:7002");
    let progress = start_progress(args, &cfg);
    if uses_engine(&eng, &cfg) {
        let engine_report = connect_and_send_engine(
            data_addr,
            ctrl_addr,
            &files,
            storage,
            &cfg,
            &eng,
            &FaultPlan::none(),
        )?;
        print_engine_report(&engine_report);
    } else {
        warn_unused_engine_flags(args);
        let report =
            connect_and_send(data_addr, ctrl_addr, &files, storage, &cfg, &FaultPlan::none())?;
        print_report(&report);
    }
    finish_obs(args, &cfg, progress)
}

fn local(args: &Args) -> Result<()> {
    let cfg = session_config(args)?;
    let eng = engine_config(args);
    let count = args.opt_u64("files", 8) as usize;
    let size = args.opt_u64("size", 16 << 20);
    let fault_count = args.opt_u64("faults", 0) as usize;
    let seed = args.opt_u64("seed", 42);

    let base = fiver::util::tmpdir::TempDir::create("fiver-local")?;
    let ds = Dataset::uniform("demo", size, count);
    eprintln!(
        "materializing {} x {} under {} ...",
        count,
        fmt::bytes(size),
        base.path().display()
    );
    ds.materialize(&base.join("src"), seed)?;
    let src: Arc<dyn Storage> = Arc::new(
        FsStorage::with_backend(&base.join("src"), cfg.io_backend)?
            .with_threshold(cfg.direct_threshold)
            .with_recorder(cfg.obs.clone()),
    );
    let dst: Arc<dyn Storage> = Arc::new(
        FsStorage::with_backend(&base.join("dst"), cfg.io_backend)?
            .with_threshold(cfg.direct_threshold)
            .with_recorder(cfg.obs.clone()),
    );
    let names: Vec<String> = ds.files.iter().map(|f| f.name.clone()).collect();
    let mut faults = FaultPlan::random(&ds, fault_count, seed);
    // Both endpoints share `cfg`'s recorder (clones share the Arc), so the
    // exports and the report's bottleneck label cover the whole pipeline.
    let progress = start_progress(args, &cfg);
    let crash_after = args.opt_u64("crash-after", 0);
    if crash_after > 0 {
        // Crash-recovery demo: kill mid-transfer, restart against the
        // journals, report what the resume saved. Needs per-endpoint
        // journal dirs; default them under the demo's scratch tree.
        faults = faults.with_crash_after_bytes(crash_after);
        let jroot = match &cfg.journal_dir {
            Some(d) => d.clone(),
            None => base.join("journal"),
        };
        let mut scfg = cfg.clone();
        scfg.journal_dir = Some(jroot.join("snd"));
        let mut rcfg = cfg.clone();
        rcfg.journal_dir = Some(jroot.join("rcv"));
        eprintln!(
            "phase 1: transferring with a planned kill after {} ...",
            fmt::bytes(crash_after)
        );
        let crashed = run_recoverable_local_transfer(
            &names,
            src.clone(),
            dst.clone(),
            &scfg,
            &rcfg,
            &eng,
            &faults,
        );
        match crashed {
            Ok(_) => eprintln!("transfer finished before the crash point — nothing to resume"),
            Err(e) => eprintln!("engine killed as planned ({e:#})"),
        }
        eprintln!("phase 2: restarting against the journals (--resume) ...");
        scfg.resume = true;
        rcfg.resume = true;
        let (engine_report, _) = run_recoverable_local_transfer(
            &names,
            src,
            dst,
            &scfg,
            &rcfg,
            &eng,
            &FaultPlan::none(),
        )?;
        print_engine_report(&engine_report);
        return finish_obs(args, &cfg, progress);
    }
    if cfg.journal_dir.is_some() {
        // `local` runs both endpoints in one process: a single journal
        // directory would have sender and receiver writing the same
        // records (and a resume would compare a record against itself),
        // so split it per endpoint, exactly like the crash demo above.
        let jroot = cfg.journal_dir.clone().expect("checked above");
        let mut scfg = cfg.clone();
        scfg.journal_dir = Some(jroot.join("snd"));
        let mut rcfg = cfg.clone();
        rcfg.journal_dir = Some(jroot.join("rcv"));
        let (engine_report, rreports) =
            run_recoverable_local_transfer(&names, src, dst, &scfg, &rcfg, &eng, &faults)?;
        print_engine_report(&engine_report);
        for (i, r) in rreports.iter().enumerate() {
            println!(
                "receiver session {i}: {} units verified, {} failed, {} repaired",
                r.units_verified,
                r.units_failed,
                fmt::bytes(r.bytes_repaired)
            );
        }
        return finish_obs(args, &cfg, progress);
    }
    if uses_engine(&eng, &cfg) || engine_only_flags_given(args) {
        let (engine_report, rreports) =
            run_parallel_local_transfer(&names, src, dst, &cfg, &eng, &faults)?;
        print_engine_report(&engine_report);
        for (i, r) in rreports.iter().enumerate() {
            println!(
                "receiver session {i}: {} units verified, {} failed, {} repaired",
                r.units_verified,
                r.units_failed,
                fmt::bytes(r.bytes_repaired)
            );
        }
    } else {
        let (report, r) = run_local_transfer(&names, src, dst, &cfg, &faults)?;
        print_report(&report);
        println!(
            "receiver: {} units verified, {} failed, {} repaired",
            r.units_verified,
            r.units_failed,
            fmt::bytes(r.bytes_repaired)
        );
    }
    finish_obs(args, &cfg, progress)
}

fn hash_cmd(args: &Args) -> Result<()> {
    let factory = hasher_factory(args.opt_or("hash", "fvr256-xla"))?;
    for path in &args.positional[1..] {
        let data = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        let mut h = factory();
        h.update(&data);
        println!("{}  {}", fiver::util::hex::encode(&h.finalize()), path);
    }
    Ok(())
}

fn print_engine_report(e: &fiver::coordinator::scheduler::EngineReport) {
    for (i, r) in e.per_session.iter().enumerate() {
        println!(
            "session {i}: {} files, {} in {} ({} failures, {} resent)",
            r.files,
            fmt::bytes(r.bytes_sent),
            fmt::secs(r.elapsed_secs),
            r.failures_detected,
            fmt::bytes(r.bytes_resent),
        );
    }
    if !e.adaptations.is_empty() {
        println!("adaptive control: {} decisions", e.adaptations.len());
        for ev in &e.adaptations {
            println!(
                "  t+{:>6.2}s {:<12} {:<7} {} -> {}  [{}]",
                ev.t_secs, ev.actuator, ev.action, ev.before, ev.after, ev.signal,
            );
        }
    }
    // Aggregate throughput is computed over the engine wall-clock
    // (EngineReport::aggregate carries it into elapsed_secs).
    print_report(&e.aggregate());
}

fn print_report(r: &fiver::coordinator::TransferReport) {
    let throughput = r.bytes_sent as f64 * 8.0 / r.elapsed_secs;
    if !r.hash_tier.is_empty() && r.hash_tier != "cryptographic" {
        println!("hash tier: {}", r.hash_tier);
    }
    println!(
        "{}: {} files, {} in {} ({}); {} failures detected, {} resent",
        r.algorithm,
        r.files,
        fmt::bytes(r.bytes_sent),
        fmt::secs(r.elapsed_secs),
        fmt::rate_bps(throughput),
        r.failures_detected,
        fmt::bytes(r.bytes_resent),
    );
    println!(
        "repair path: {} rounds, {} re-read from source, {} verification RTTs",
        r.repair_rounds,
        fmt::bytes(r.bytes_reread),
        r.verify_rtts,
    );
    if !r.io_backend.is_empty() || r.pool_peak_in_flight > 0 || r.pool_fallback_allocs > 0 {
        let backend = if r.io_backend.is_empty() { "?" } else { &r.io_backend };
        println!(
            "data plane: backend={backend}, {} pooled buffers peak in flight, \
             {} fallback allocs, {} pool grows, {} storage syncs, {} direct fallbacks",
            r.pool_peak_in_flight,
            r.pool_fallback_allocs,
            r.pool_grow_events,
            r.storage_syncs,
            r.direct_fallbacks,
        );
    }
    if r.uring_fallbacks > 0 || r.storage_hints > 0 {
        println!(
            "data plane: {} uring fallbacks, {} storage hints issued",
            r.uring_fallbacks, r.storage_hints,
        );
    }
    if !r.file_backends.is_empty() {
        // `auto` records the engine picked per file; cap the listing so
        // large batches don't flood the report.
        let shown: Vec<String> = r
            .file_backends
            .iter()
            .take(8)
            .map(|(name, backend)| format!("{name}={backend}"))
            .collect();
        let more = r.file_backends.len().saturating_sub(8);
        let suffix = if more > 0 { format!(" (+{more} more)") } else { String::new() };
        println!("auto backend: {}{suffix}", shown.join(", "));
    }
    for s in &r.stage_stats {
        println!(
            "stage {:<10} {:>9} spans, busy {:>9}, p50 {:>7}µs, p95 {:>7}µs, p99 {:>7}µs",
            s.stage,
            s.count,
            fmt::secs(s.busy_secs),
            s.p50_us,
            s.p95_us,
            s.p99_us,
        );
    }
    if !r.bottleneck.is_empty() {
        let dropped = if r.trace_dropped > 0 {
            format!(", {} trace events dropped", r.trace_dropped)
        } else {
            String::new()
        };
        println!(
            "bottleneck: {} (confidence {}{dropped})",
            r.bottleneck,
            fiver::obs::cli_confidence(r.bottleneck_confidence),
        );
    }
    if r.files_skipped > 0 || r.bytes_skipped > 0 {
        println!(
            "resume: {} files verified from the journal, {} not re-sent",
            r.files_skipped,
            fmt::bytes(r.bytes_skipped),
        );
    }
    if r.bytes_skipped_delta > 0 || r.leaves_clean > 0 || r.leaves_dirty > 0 {
        println!(
            "delta: {} matched from the receiver's data and not re-sent \
             ({} clean leaves, {} dirty)",
            fmt::bytes(r.bytes_skipped_delta),
            r.leaves_clean,
            r.leaves_dirty,
        );
    }
    if r.delta_scans_skipped > 0 {
        println!(
            "delta: {} rolling scans skipped (sender signature cache)",
            r.delta_scans_skipped,
        );
    }
}
