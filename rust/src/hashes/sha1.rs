//! SHA-1 (RFC 3174), implemented from scratch.
//!
//! The paper's Fig 10 midpoint: ~1.5x the checksum cost of MD5 on its
//! testbed. Verified against the RFC 3174 / FIPS 180 test vectors.

use super::Hasher;

const INIT: [u32; 5] = [0x67452301, 0xEFCDAB89, 0x98BADCFE, 0x10325476, 0xC3D2E1F0];

/// Streaming SHA-1 state.
pub struct Sha1 {
    state: [u32; 5],
    len: u64,
    buf: [u8; 64],
    buf_len: usize,
}

impl Default for Sha1 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha1 {
    /// A fresh hasher.
    pub fn new() -> Self {
        Sha1 { state: INIT, len: 0, buf: [0; 64], buf_len: 0 }
    }

    fn compress(state: &mut [u32; 5], block: &[u8; 64]) {
        let mut w = [0u32; 80];
        for i in 0..16 {
            w[i] = u32::from_be_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
        }
        for i in 16..80 {
            w[i] = (w[i - 3] ^ w[i - 8] ^ w[i - 14] ^ w[i - 16]).rotate_left(1);
        }
        let [mut a, mut b, mut c, mut d, mut e] = *state;
        for (i, &wi) in w.iter().enumerate() {
            let (f, k) = match i / 20 {
                0 => ((b & c) | (!b & d), 0x5A827999),
                1 => (b ^ c ^ d, 0x6ED9EBA1),
                2 => ((b & c) | (b & d) | (c & d), 0x8F1BBCDC),
                _ => (b ^ c ^ d, 0xCA62C1D6),
            };
            let tmp = a
                .rotate_left(5)
                .wrapping_add(f)
                .wrapping_add(e)
                .wrapping_add(k)
                .wrapping_add(wi);
            e = d;
            d = c;
            c = b.rotate_left(30);
            b = a;
            a = tmp;
        }
        state[0] = state[0].wrapping_add(a);
        state[1] = state[1].wrapping_add(b);
        state[2] = state[2].wrapping_add(c);
        state[3] = state[3].wrapping_add(d);
        state[4] = state[4].wrapping_add(e);
    }
}

impl Hasher for Sha1 {
    fn update(&mut self, mut data: &[u8]) {
        self.len = self.len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len < 64 {
                return; // staged only; nothing else to process
            }
            let block = self.buf;
            Self::compress(&mut self.state, &block);
            self.buf_len = 0;
        }
        let mut chunks = data.chunks_exact(64);
        for block in &mut chunks {
            Self::compress(&mut self.state, block.try_into().unwrap());
        }
        let rem = chunks.remainder();
        self.buf[..rem.len()].copy_from_slice(rem);
        self.buf_len = rem.len();
    }

    fn finalize(&mut self) -> Vec<u8> {
        let bit_len = self.len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        Self::compress(&mut self.state, &block);
        self.buf_len = 0;
        self.state.iter().flat_map(|w| w.to_be_bytes()).collect()
    }

    fn digest_len(&self) -> usize {
        20
    }

    fn reset(&mut self) {
        *self = Sha1::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashes::Hasher;
    use crate::util::hex;

    fn sha1_hex(data: &[u8]) -> String {
        let mut h = Sha1::new();
        h.update(data);
        hex::encode(&h.finalize())
    }

    /// FIPS 180 / RFC 3174 vectors.
    #[test]
    fn fips_vectors() {
        assert_eq!(sha1_hex(b""), "da39a3ee5e6b4b0d3255bfef95601890afd80709");
        assert_eq!(sha1_hex(b"abc"), "a9993e364706816aba3e25717850c26c9cd0d89d");
        assert_eq!(
            sha1_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "84983e441c3bd26ebaae4aa1f95129e5e54670f1"
        );
    }

    #[test]
    fn one_million_a() {
        let mut h = Sha1::new();
        let chunk = [0x61u8; 1000];
        for _ in 0..1000 {
            h.update(&chunk);
        }
        assert_eq!(
            hex::encode(&h.finalize()),
            "34aa973cd4c4daa4f61eeb2bdbad27316534016f"
        );
    }

    #[test]
    fn split_update_invariance() {
        let data: Vec<u8> = (0u8..=255).cycle().take(777).collect();
        let whole = sha1_hex(&data);
        for split in [1usize, 63, 64, 65, 100] {
            let mut h = Sha1::new();
            for part in data.chunks(split) {
                h.update(part);
            }
            assert_eq!(hex::encode(&h.finalize()), whole, "split {split}");
        }
    }
}
