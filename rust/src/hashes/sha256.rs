//! SHA-256 (FIPS 180-4 / RFC 6234), implemented from scratch.
//!
//! The paper's most expensive hash (Fig 10: ~2.2x MD5's checksum time).
//! Verified against the FIPS 180-4 test vectors.

use super::Hasher;

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const INIT: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 state.
pub struct Sha256 {
    state: [u32; 8],
    len: u64,
    buf: [u8; 64],
    buf_len: usize,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// A fresh hasher.
    pub fn new() -> Self {
        Sha256 { state: INIT, len: 0, buf: [0; 64], buf_len: 0 }
    }

    fn compress(state: &mut [u32; 8], block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

impl Hasher for Sha256 {
    fn update(&mut self, mut data: &[u8]) {
        self.len = self.len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len < 64 {
                return; // staged only; nothing else to process
            }
            let block = self.buf;
            Self::compress(&mut self.state, &block);
            self.buf_len = 0;
        }
        let mut chunks = data.chunks_exact(64);
        for block in &mut chunks {
            Self::compress(&mut self.state, block.try_into().unwrap());
        }
        let rem = chunks.remainder();
        self.buf[..rem.len()].copy_from_slice(rem);
        self.buf_len = rem.len();
    }

    fn finalize(&mut self) -> Vec<u8> {
        let bit_len = self.len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        Self::compress(&mut self.state, &block);
        self.buf_len = 0;
        self.state.iter().flat_map(|w| w.to_be_bytes()).collect()
    }

    fn digest_len(&self) -> usize {
        32
    }

    fn reset(&mut self) {
        *self = Sha256::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashes::Hasher;
    use crate::util::hex;

    fn sha256_hex(data: &[u8]) -> String {
        let mut h = Sha256::new();
        h.update(data);
        hex::encode(&h.finalize())
    }

    /// FIPS 180-4 vectors.
    #[test]
    fn fips_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn one_million_a() {
        let mut h = Sha256::new();
        let chunk = [0x61u8; 1000];
        for _ in 0..1000 {
            h.update(&chunk);
        }
        assert_eq!(
            hex::encode(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn split_update_invariance() {
        let data: Vec<u8> = (0u8..=255).cycle().take(500).collect();
        let whole = sha256_hex(&data);
        for split in [1usize, 7, 63, 64, 65] {
            let mut h = Sha256::new();
            for part in data.chunks(split) {
                h.update(part);
            }
            assert_eq!(hex::encode(&h.finalize()), whole, "split {split}");
        }
    }
}
