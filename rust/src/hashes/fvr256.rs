//! FVR-256 — native Rust port of the block-parallel hash whose normative
//! definition is the Pallas kernel (`python/compile/kernels/fvr_hash.py`).
//!
//! Bit-exact with both the Pallas kernel (hence the AOT HLO artifacts) and
//! the plain-python `PyFvr256`; cross-checked in tests against
//! `artifacts/test_vectors.json`. The PJRT execution path
//! ([`crate::runtime::FvrHasher`]) offloads the *chunk* digest to the
//! compiled XLA artifact and chains chunk digests with [`absorb8`] exactly
//! as this module does, so the two paths are interchangeable.
//!
//! Layout recap (see the kernel docstring for the rationale):
//! stream -> chunks of `B*W*4` bytes -> B blocks of W u32 words (LE)
//! -> per-block absorb8 fold from IV -> binary-tree combine
//! -> chunk finalize (true length + chunk index + geometry)
//! -> stream chain: state = absorb8(state, chunk_digest), then final
//!    absorb8 with [total_lo, total_hi, nchunks, MAGIC_F, MAGIC_R, 0, 0, 0].

use super::Hasher;

/// Number of parallel mixing lanes.
pub const LANES: usize = 8;
/// Mixing multiplier 1 (golden-ratio prime).
pub const M1: u32 = 0x9E3779B1;
/// Mixing multiplier 2.
pub const M2: u32 = 0x85EBCA77;
/// Per-chunk offset constant.
pub const C0: u32 = 0x7F4A7C15;
/// Domain-separation constant (ASCII `FIVE`).
pub const MAGIC_F: u32 = 0x46495645;
/// Finalization constant.
pub const MAGIC_R: u32 = 0x52C3D2E1;

/// Initial state vector.
pub const IV: [u32; 8] = [
    0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
    0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19,
];

/// The FVR-256 round function: absorb an 8-word message into an 8-word
/// state. Must match `fvr_hash.absorb8` / `ref._absorb8` bit-for-bit.
#[inline]
pub fn absorb8(state: &[u32; 8], m: &[u32; 8]) -> [u32; 8] {
    let mut s = [0u32; 8];
    for i in 0..8 {
        s[i] = state[i].wrapping_add(C0) ^ m[i].rotate_left(9);
    }
    for x in s.iter_mut() {
        *x = x.wrapping_mul(M1).rotate_left(13);
    }
    let mut t = [0u32; 8];
    for i in 0..8 {
        // roll(-1): lane i sees lane (i+1) % 8
        t[i] = s[i].wrapping_add(s[(i + 1) % 8].rotate_left(7));
    }
    for x in t.iter_mut() {
        *x = x.wrapping_mul(M2);
        *x ^= *x >> 16;
    }
    t
}

/// Hash geometry: how the stream is cut into chunks and blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    /// Blocks per chunk (power of two).
    pub num_blocks: usize,
    /// u32 words per block (multiple of 8).
    pub words_per_block: usize,
}

impl Geometry {
    /// A geometry of `num_blocks` blocks x `words_per_block` words.
    pub const fn new(num_blocks: usize, words_per_block: usize) -> Geometry {
        Geometry { num_blocks, words_per_block }
    }

    /// Check the geometry against kernel limits.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.num_blocks.is_power_of_two(), "num_blocks must be a power of two");
        anyhow::ensure!(
            self.words_per_block % LANES == 0,
            "words_per_block must be a multiple of 8"
        );
        anyhow::ensure!(self.words_per_block > 0, "words_per_block must be positive");
        Ok(())
    }

    /// Words consumed per chunk.
    pub const fn chunk_words(&self) -> usize {
        self.num_blocks * self.words_per_block
    }

    /// Bytes consumed per chunk.
    pub const fn chunk_bytes(&self) -> usize {
        self.chunk_words() * 4
    }

    /// The default 1 MiB geometry (matches artifact variant "1m").
    pub const DEFAULT: Geometry = Geometry::new(64, 4096);
    /// 256 KiB geometry (artifact variant "256k").
    pub const SMALL: Geometry = Geometry::new(16, 4096);
    /// 4 MiB geometry (artifact variant "4m").
    pub const LARGE: Geometry = Geometry::new(256, 4096);
    /// Tiny geometry for tests (64-byte chunks).
    pub const TINY: Geometry = Geometry::new(2, 8);
}

/// Digest one block of `words_per_block` u32 words.
pub fn block_digest(words: &[u32]) -> [u32; 8] {
    debug_assert_eq!(words.len() % LANES, 0);
    let mut state = IV;
    for group in words.chunks_exact(LANES) {
        state = absorb8(&state, group.try_into().unwrap());
    }
    state
}

/// Load one 32-byte group as 8 LE words (hot path; compiles to plain
/// unaligned loads).
#[inline]
fn load_group(bytes: &[u8]) -> [u32; 8] {
    let mut m = [0u32; 8];
    for (i, w) in m.iter_mut().enumerate() {
        *w = u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
    }
    m
}

/// Digest one block directly from bytes (`len == words_per_block * 4`),
/// avoiding the intermediate word buffer — the streaming hot path.
pub fn block_digest_bytes(bytes: &[u8]) -> [u32; 8] {
    debug_assert_eq!(bytes.len() % (LANES * 4), 0);
    let mut state = IV;
    for group in bytes.chunks_exact(LANES * 4) {
        state = absorb8(&state, &load_group(group));
    }
    state
}

/// Digest a *full* chunk directly from bytes with no allocation:
/// `data.len()` must equal `geo.chunk_bytes()`.
pub fn chunk_digest_full(geo: Geometry, data: &[u8], chunk_index: u64) -> [u32; 8] {
    assert_eq!(data.len(), geo.chunk_bytes(), "chunk_digest_full needs a full chunk");
    let block_bytes = geo.words_per_block * 4;
    let mut digests: Vec<[u32; 8]> = data
        .chunks_exact(block_bytes)
        .map(block_digest_bytes)
        .collect();
    while digests.len() > 1 {
        digests = digests.chunks_exact(2).map(|p| absorb8(&p[0], &p[1])).collect();
    }
    let meta = [
        data.len() as u32,
        chunk_index as u32,
        MAGIC_F,
        MAGIC_R,
        geo.num_blocks as u32,
        geo.words_per_block as u32,
        0,
        0,
    ];
    absorb8(&digests[0], &meta)
}

/// Digest a full (padded) chunk given as words, binding the true byte
/// length and stream position. `words.len()` must equal `geo.chunk_words()`.
pub fn chunk_digest_words(
    geo: Geometry,
    words: &[u32],
    true_len: u64,
    chunk_index: u64,
) -> [u32; 8] {
    assert_eq!(words.len(), geo.chunk_words(), "chunk word count mismatch");
    let w = geo.words_per_block;
    let mut digests: Vec<[u32; 8]> = (0..geo.num_blocks)
        .map(|b| block_digest(&words[b * w..(b + 1) * w]))
        .collect();
    while digests.len() > 1 {
        digests = digests.chunks_exact(2).map(|p| absorb8(&p[0], &p[1])).collect();
    }
    let meta = [
        true_len as u32,
        chunk_index as u32,
        MAGIC_F,
        MAGIC_R,
        geo.num_blocks as u32,
        geo.words_per_block as u32,
        0,
        0,
    ];
    absorb8(&digests[0], &meta)
}

/// Digest one (possibly short) chunk of bytes: zero-pad to chunk size, pack
/// into LE words, and run [`chunk_digest_words`].
pub fn chunk_digest_bytes(geo: Geometry, data: &[u8], chunk_index: u64) -> [u32; 8] {
    assert!(data.len() <= geo.chunk_bytes(), "chunk too large for geometry");
    let words = pack_words(geo, data);
    chunk_digest_words(geo, &words, data.len() as u64, chunk_index)
}

/// Pack bytes into the chunk's u32 LE word array, zero-padded.
pub fn pack_words(geo: Geometry, data: &[u8]) -> Vec<u32> {
    let mut words = vec![0u32; geo.chunk_words()];
    let mut iter = data.chunks_exact(4);
    let mut i = 0;
    for c in &mut iter {
        words[i] = u32::from_le_bytes(c.try_into().unwrap());
        i += 1;
    }
    let rem = iter.remainder();
    if !rem.is_empty() {
        let mut last = [0u8; 4];
        last[..rem.len()].copy_from_slice(rem);
        words[i] = u32::from_le_bytes(last);
    }
    words
}

/// Streaming FVR-256 hasher (native compute path).
pub struct Fvr256 {
    geo: Geometry,
    buf: Vec<u8>,
    state: [u32; 8],
    chunk_index: u64,
    total: u64,
}

impl Default for Fvr256 {
    fn default() -> Self {
        Self::new(Geometry::DEFAULT)
    }
}

impl Fvr256 {
    /// A hasher with the given geometry.
    pub fn new(geo: Geometry) -> Self {
        geo.validate().expect("invalid geometry");
        Fvr256 {
            geo,
            buf: Vec::with_capacity(geo.chunk_bytes()),
            state: IV,
            chunk_index: 0,
            total: 0,
        }
    }

    /// The configured geometry.
    pub fn geometry(&self) -> Geometry {
        self.geo
    }

    fn absorb_chunk(&mut self, data: &[u8]) {
        // Full chunks take the allocation-free byte path; only the final
        // partial chunk pays for padding/packing.
        let cd = if data.len() == self.geo.chunk_bytes() {
            chunk_digest_full(self.geo, data, self.chunk_index)
        } else {
            chunk_digest_bytes(self.geo, data, self.chunk_index)
        };
        self.state = absorb8(&self.state, &cd);
        self.chunk_index += 1;
    }

    /// Final file digest as 8 u32 words (the convention the coordinator
    /// exchanges over the control channel).
    pub fn digest_words(&mut self) -> [u32; 8] {
        if !self.buf.is_empty() {
            let tail = std::mem::take(&mut self.buf);
            self.absorb_chunk(&tail);
        }
        let meta = [
            self.total as u32,
            (self.total >> 32) as u32,
            self.chunk_index as u32,
            MAGIC_F,
            MAGIC_R,
            0,
            0,
            0,
        ];
        absorb8(&self.state, &meta)
    }
}

impl Hasher for Fvr256 {
    fn update(&mut self, mut data: &[u8]) {
        self.total += data.len() as u64;
        let cb = self.geo.chunk_bytes();
        // Top up the staging buffer first (one memcpy for misaligned
        // input), absorbing in place when it fills — no drain/realloc.
        if !self.buf.is_empty() {
            let need = cb - self.buf.len();
            let take = need.min(data.len());
            self.buf.extend_from_slice(&data[..take]);
            data = &data[take..];
            if self.buf.len() == cb {
                let buf = std::mem::take(&mut self.buf);
                self.absorb_chunk(&buf);
                self.buf = buf;
                self.buf.clear();
            }
        }
        // Full chunks straight from the input: zero staging copies.
        while data.len() >= cb {
            let (chunk, rest) = data.split_at(cb);
            self.absorb_chunk(chunk);
            data = rest;
        }
        self.buf.extend_from_slice(data);
    }

    fn finalize(&mut self) -> Vec<u8> {
        let words = self.digest_words();
        // Hex convention: each word rendered big-endian ("{w:08x}") — so the
        // byte digest is the words in BE order.
        words.iter().flat_map(|w| w.to_be_bytes()).collect()
    }

    fn digest_len(&self) -> usize {
        32
    }

    fn reset(&mut self) {
        let geo = self.geo;
        *self = Fvr256::new(geo);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashes::Hasher;
    use crate::util::hex;

    fn fvr_hex(data: &[u8], geo: Geometry) -> String {
        let mut h = Fvr256::new(geo);
        h.update(data);
        hex::encode(&h.finalize())
    }

    #[test]
    fn absorb8_not_identity_on_zero() {
        let out = absorb8(&[0; 8], &[0; 8]);
        assert!(out.iter().any(|&x| x != 0));
    }

    #[test]
    fn absorb8_asymmetric() {
        let a = [1u32, 2, 3, 4, 5, 6, 7, 8];
        let b = [8u32, 7, 6, 5, 4, 3, 2, 1];
        assert_ne!(absorb8(&a, &b), absorb8(&b, &a));
    }

    #[test]
    fn tree_combine_order_sensitive() {
        let geo = Geometry::TINY;
        let mut a = vec![0u8; geo.chunk_bytes()];
        a[0] = 1; // block 0 differs from block 1
        let mut b = vec![0u8; geo.chunk_bytes()];
        b[geo.words_per_block * 4] = 1; // mirrored into block 1
        assert_ne!(chunk_digest_bytes(geo, &a, 0), chunk_digest_bytes(geo, &b, 0));
    }

    #[test]
    fn padding_distinct_from_explicit_zero() {
        let geo = Geometry::TINY;
        assert_ne!(fvr_hex(b"abc", geo), fvr_hex(b"abc\x00", geo));
    }

    #[test]
    fn split_update_invariance() {
        let geo = Geometry::TINY;
        let data: Vec<u8> = (0u8..=255).cycle().take(777).collect();
        let whole = fvr_hex(&data, geo);
        for split in [1usize, 7, 63, 64, 65, 128] {
            let mut h = Fvr256::new(geo);
            for part in data.chunks(split) {
                h.update(part);
            }
            assert_eq!(hex::encode(&h.finalize()), whole, "split {split}");
        }
    }

    #[test]
    fn chunk_boundary_lengths() {
        let geo = Geometry::TINY;
        let cb = geo.chunk_bytes();
        for n in [0, 1, cb - 1, cb, cb + 1, 2 * cb, 2 * cb + 17] {
            let data = vec![0xA5u8; n];
            let whole = fvr_hex(&data, geo);
            let mut h = Fvr256::new(geo);
            h.update(&data[..n / 3]);
            h.update(&data[n / 3..]);
            assert_eq!(hex::encode(&h.finalize()), whole, "len {n}");
        }
    }

    #[test]
    fn geometry_bound_into_digest() {
        let data = vec![7u8; 256];
        assert_ne!(fvr_hex(&data, Geometry::TINY), fvr_hex(&data, Geometry::new(4, 8)));
    }

    #[test]
    fn pack_words_le() {
        let geo = Geometry::TINY;
        let words = pack_words(geo, &[0x01, 0x02, 0x03, 0x04, 0xAA]);
        assert_eq!(words[0], 0x04030201);
        assert_eq!(words[1], 0x000000AA);
        assert_eq!(words[2], 0);
    }

    #[test]
    fn rejects_bad_geometry() {
        assert!(Geometry::new(3, 8).validate().is_err());
        assert!(Geometry::new(2, 12).validate().is_err());
        assert!(Geometry::new(2, 0).validate().is_err());
    }

    /// Vector pinned from the python implementation:
    /// `ref.fvr256_hex(b"hello world")` with default geometry.
    #[test]
    fn python_pinned_vector() {
        assert_eq!(
            fvr_hex(b"hello world", Geometry::DEFAULT),
            "86a087538e0dd3bccffe9beb47a9df2872fc093a63e91ebe5cf7a05c314ff9e6"
        );
    }
}
