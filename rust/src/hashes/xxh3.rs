//! XXH3-64 / XXH3-128 — from-scratch port of the xxHash3 specification
//! (https://github.com/Cyan4973/xxHash, public-domain reference), same
//! no-new-crates discipline as the MD5/SHA/FVR-256 siblings.
//!
//! XXH3 is the *fast tier* of the tiered integrity plane: a
//! non-cryptographic checksum running at close to memory speed, used for
//! leaf and transport digests while a cryptographic [`super::HashAlgorithm`]
//! anchors the Merkle root (see DESIGN.md, "Tiered hashing"). Only the
//! seedless (seed = 0, default-secret) variant is implemented — tier
//! selection never needs seeding, and the seedless path is the fast one.
//!
//! The streaming state mirrors the reference: inputs ≤ 240 bytes are
//! buffered whole and dispatched to the length-stratified short paths at
//! finalize; longer streams run the 8-lane accumulator over 64-byte
//! stripes (16 stripes per 1024-byte block, scramble between blocks) with
//! a 64-byte lookback for the final stripe. Digest bytes are emitted in
//! the canonical (big-endian) order, matching `XXH64_canonicalFromHash` /
//! `XXH128_canonicalFromHash`, so hex digests agree with every other
//! xxHash implementation.

use super::Hasher;

const P32_1: u64 = 0x9E3779B1;
const P32_2: u64 = 0x85EBCA77;
const P32_3: u64 = 0xC2B2AE3D;
const P64_1: u64 = 0x9E3779B185EBCA87;
const P64_2: u64 = 0xC2B2AE3D27D4EB4F;
const P64_3: u64 = 0x165667B19E3779F9;
const P64_4: u64 = 0x85EBCA77C2B2AE63;
const P64_5: u64 = 0x27D4EB2F165667C5;
const PMX1: u64 = 0x165667919E3779F9;
const PMX2: u64 = 0x9FB21C651E98DF25;

/// The 192-byte default secret (`XXH3_kSecret`).
const SECRET: [u8; 192] = [
    0xb8, 0xfe, 0x6c, 0x39, 0x23, 0xa4, 0x4b, 0xbe, 0x7c, 0x01, 0x81, 0x2c, 0xf7, 0x21, 0xad,
    0x1c, 0xde, 0xd4, 0x6d, 0xe9, 0x83, 0x90, 0x97, 0xdb, 0x72, 0x40, 0xa4, 0xa4, 0xb7, 0xb3,
    0x67, 0x1f, 0xcb, 0x79, 0xe6, 0x4e, 0xcc, 0xc0, 0xe5, 0x78, 0x82, 0x5a, 0xd0, 0x7d, 0xcc,
    0xff, 0x72, 0x21, 0xb8, 0x08, 0x46, 0x74, 0xf7, 0x43, 0x24, 0x8e, 0xe0, 0x35, 0x90, 0xe6,
    0x81, 0x3a, 0x26, 0x4c, 0x3c, 0x28, 0x52, 0xbb, 0x91, 0xc3, 0x00, 0xcb, 0x88, 0xd0, 0x65,
    0x8b, 0x1b, 0x53, 0x2e, 0xa3, 0x71, 0x64, 0x48, 0x97, 0xa2, 0x0d, 0xf9, 0x4e, 0x38, 0x19,
    0xef, 0x46, 0xa9, 0xde, 0xac, 0xd8, 0xa8, 0xfa, 0x76, 0x3f, 0xe3, 0x9c, 0x34, 0x3f, 0xf9,
    0xdc, 0xbb, 0xc7, 0xc7, 0x0b, 0x4f, 0x1d, 0x8a, 0x51, 0xe0, 0x4b, 0xcd, 0xb4, 0x59, 0x31,
    0xc8, 0x9f, 0x7e, 0xc9, 0xd9, 0x78, 0x73, 0x64, 0xea, 0xc5, 0xac, 0x83, 0x34, 0xd3, 0xeb,
    0xc3, 0xc5, 0x81, 0xa0, 0xff, 0xfa, 0x13, 0x63, 0xeb, 0x17, 0x0d, 0xdd, 0x51, 0xb7, 0xf0,
    0xda, 0x49, 0xd3, 0x16, 0x55, 0x26, 0x29, 0xd4, 0x68, 0x9e, 0x2b, 0x16, 0xbe, 0x58, 0x7d,
    0x47, 0xa1, 0xfc, 0x8f, 0xf8, 0xb8, 0xd1, 0x7a, 0xd0, 0x31, 0xce, 0x45, 0xcb, 0x3a, 0x8f,
    0x95, 0x16, 0x04, 0x28, 0xaf, 0xd7, 0xfb, 0xca, 0xbb, 0x4b, 0x40, 0x7e,
];

/// Stripes per block with the default secret: `(192 - 64) / 8`.
const STRIPES_PER_BLOCK: usize = 16;
/// Secret offset of the final-stripe key (`secretLimit - 7`).
const LAST_STRIPE_SECRET: usize = 192 - 64 - 7;
/// Secret offset where the low-half merge keys start.
const MERGE_SECRET_LO: usize = 11;
/// Secret offset where the high-half merge keys start (128-bit only).
const MERGE_SECRET_HI: usize = 192 - 64 - 11;
/// Secret offset of the 129..=240 "midsize" rounds past the first eight.
const MIDSIZE_SECRET: usize = 3;
/// Secret offset of the 129..=240 last mix (64-bit path).
const MIDSIZE_LAST_SECRET: usize = 136 - 17;

#[inline]
fn r32(b: &[u8], i: usize) -> u64 {
    u32::from_le_bytes(b[i..i + 4].try_into().unwrap()) as u64
}

#[inline]
fn r64(b: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(b[i..i + 8].try_into().unwrap())
}

/// Full 64x64→128 multiply folded to 64 bits by XOR of the halves.
#[inline]
fn fold64(a: u64, b: u64) -> u64 {
    let p = (a as u128) * (b as u128);
    (p as u64) ^ ((p >> 64) as u64)
}

#[inline]
fn xorshift(v: u64, s: u32) -> u64 {
    v ^ (v >> s)
}

/// `XXH3_avalanche`: the fast final mix for well-mixed inputs.
#[inline]
fn avalanche(mut h: u64) -> u64 {
    h = xorshift(h, 37);
    h = h.wrapping_mul(PMX1);
    xorshift(h, 32)
}

/// `XXH64_avalanche`: the classic XXH64 finalizer, used by the tiny paths.
#[inline]
fn avalanche64(mut h: u64) -> u64 {
    h = xorshift(h, 33);
    h = h.wrapping_mul(P64_2);
    h = xorshift(h, 29);
    h = h.wrapping_mul(P64_3);
    xorshift(h, 32)
}

/// `XXH3_rrmxmx`: stronger finalizer for the 4..=8 byte path.
#[inline]
fn rrmxmx(mut h: u64, len: u64) -> u64 {
    h ^= h.rotate_left(49) ^ h.rotate_left(24);
    h = h.wrapping_mul(PMX2);
    h ^= (h >> 35).wrapping_add(len);
    h = h.wrapping_mul(PMX2);
    xorshift(h, 28)
}

/// Mix 16 input bytes with 16 secret bytes into one folded word.
#[inline]
fn mix16(b: &[u8], off: usize, soff: usize) -> u64 {
    fold64(r64(b, off) ^ r64(&SECRET, soff), r64(b, off + 8) ^ r64(&SECRET, soff + 8))
}

// ---- 64-bit short paths (len <= 240) ----

fn len_0to16_64(b: &[u8]) -> u64 {
    let n = b.len();
    if n > 8 {
        let lo = r64(b, 0) ^ (r64(&SECRET, 24) ^ r64(&SECRET, 32));
        let hi = r64(b, n - 8) ^ (r64(&SECRET, 40) ^ r64(&SECRET, 48));
        let acc = (n as u64)
            .wrapping_add(lo.swap_bytes())
            .wrapping_add(hi)
            .wrapping_add(fold64(lo, hi));
        avalanche(acc)
    } else if n >= 4 {
        let keyed = (r32(b, n - 4) | (r32(b, 0) << 32)) ^ (r64(&SECRET, 8) ^ r64(&SECRET, 16));
        rrmxmx(keyed, n as u64)
    } else if n > 0 {
        let combined = ((b[0] as u64) << 16)
            | ((b[n >> 1] as u64) << 24)
            | (b[n - 1] as u64)
            | ((n as u64) << 8);
        avalanche64(combined ^ (r32(&SECRET, 0) ^ r32(&SECRET, 4)))
    } else {
        avalanche64(r64(&SECRET, 56) ^ r64(&SECRET, 64))
    }
}

fn len_17to128_64(b: &[u8]) -> u64 {
    let n = b.len();
    let mut acc = (n as u64).wrapping_mul(P64_1);
    if n > 32 {
        if n > 64 {
            if n > 96 {
                acc = acc.wrapping_add(mix16(b, 48, 96));
                acc = acc.wrapping_add(mix16(b, n - 64, 112));
            }
            acc = acc.wrapping_add(mix16(b, 32, 64));
            acc = acc.wrapping_add(mix16(b, n - 48, 80));
        }
        acc = acc.wrapping_add(mix16(b, 16, 32));
        acc = acc.wrapping_add(mix16(b, n - 32, 48));
    }
    acc = acc.wrapping_add(mix16(b, 0, 0));
    acc = acc.wrapping_add(mix16(b, n - 16, 16));
    avalanche(acc)
}

fn len_129to240_64(b: &[u8]) -> u64 {
    let n = b.len();
    let mut acc = (n as u64).wrapping_mul(P64_1);
    for i in 0..8 {
        acc = acc.wrapping_add(mix16(b, 16 * i, 16 * i));
    }
    acc = avalanche(acc);
    for i in 8..n / 16 {
        acc = acc.wrapping_add(mix16(b, 16 * i, 16 * (i - 8) + MIDSIZE_SECRET));
    }
    acc = acc.wrapping_add(mix16(b, n - 16, MIDSIZE_LAST_SECRET));
    avalanche(acc)
}

// ---- 128-bit short paths (len <= 240) ----

fn len_0to16_128(b: &[u8]) -> (u64, u64) {
    let n = b.len();
    if n > 8 {
        let inl = r64(b, 0);
        let mut inh = r64(b, n - 8);
        let p = (inl ^ inh ^ (r64(&SECRET, 32) ^ r64(&SECRET, 40))) as u128 * P64_1 as u128;
        let mut mlo = (p as u64).wrapping_add(((n as u64) - 1) << 54);
        inh ^= r64(&SECRET, 48) ^ r64(&SECRET, 56);
        let mut mhi = ((p >> 64) as u64)
            .wrapping_add(inh)
            .wrapping_add((inh & 0xFFFF_FFFF).wrapping_mul(P32_2 - 1));
        mlo ^= mhi.swap_bytes();
        let h = (mlo as u128) * (P64_2 as u128);
        let hlo = h as u64;
        mhi = ((h >> 64) as u64).wrapping_add(mhi.wrapping_mul(P64_2));
        (avalanche(hlo), avalanche(mhi))
    } else if n >= 4 {
        let keyed = (r32(b, 0) | (r32(b, n - 4) << 32)) ^ (r64(&SECRET, 16) ^ r64(&SECRET, 24));
        let p = (keyed as u128) * (P64_1.wrapping_add((n as u64) << 2) as u128);
        let mut lo = p as u64;
        let mut hi = ((p >> 64) as u64).wrapping_add(lo << 1);
        lo ^= hi >> 3;
        lo = xorshift(lo, 35);
        lo = lo.wrapping_mul(PMX2);
        lo = xorshift(lo, 28);
        hi = avalanche(hi);
        (lo, hi)
    } else if n > 0 {
        let combl = (((b[0] as u32) << 16)
            | ((b[n >> 1] as u32) << 24)
            | (b[n - 1] as u32)
            | ((n as u32) << 8)) as u64;
        let combh = (combl as u32).swap_bytes().rotate_left(13) as u64;
        let lo = avalanche64(combl ^ (r32(&SECRET, 0) ^ r32(&SECRET, 4)));
        let hi = avalanche64(combh ^ (r32(&SECRET, 8) ^ r32(&SECRET, 12)));
        (lo, hi)
    } else {
        let lo = avalanche64(r64(&SECRET, 64) ^ r64(&SECRET, 72));
        let hi = avalanche64(r64(&SECRET, 80) ^ r64(&SECRET, 88));
        (lo, hi)
    }
}

/// `XXH128_mix32B`: one 32-byte round of the midsize 128-bit paths.
#[inline]
fn mix32(acc: (u64, u64), b: &[u8], off1: usize, off2: usize, soff: usize) -> (u64, u64) {
    let (mut al, mut ah) = acc;
    al = al.wrapping_add(mix16(b, off1, soff));
    al ^= r64(b, off2).wrapping_add(r64(b, off2 + 8));
    ah = ah.wrapping_add(mix16(b, off2, soff + 16));
    ah ^= r64(b, off1).wrapping_add(r64(b, off1 + 8));
    (al, ah)
}

#[inline]
fn fin128(al: u64, ah: u64, n: u64) -> (u64, u64) {
    let lo = al.wrapping_add(ah);
    let hi = al
        .wrapping_mul(P64_1)
        .wrapping_add(ah.wrapping_mul(P64_4))
        .wrapping_add(n.wrapping_mul(P64_2));
    (avalanche(lo), avalanche(hi).wrapping_neg())
}

fn len_17to128_128(b: &[u8]) -> (u64, u64) {
    let n = b.len();
    let mut acc = ((n as u64).wrapping_mul(P64_1), 0u64);
    if n > 32 {
        if n > 64 {
            if n > 96 {
                acc = mix32(acc, b, 48, n - 64, 96);
            }
            acc = mix32(acc, b, 32, n - 48, 64);
        }
        acc = mix32(acc, b, 16, n - 32, 32);
    }
    acc = mix32(acc, b, 0, n - 16, 0);
    fin128(acc.0, acc.1, n as u64)
}

fn len_129to240_128(b: &[u8]) -> (u64, u64) {
    let n = b.len();
    let mut acc = ((n as u64).wrapping_mul(P64_1), 0u64);
    for i in 0..4 {
        acc = mix32(acc, b, 32 * i, 32 * i + 16, 32 * i);
    }
    acc = (avalanche(acc.0), avalanche(acc.1));
    for i in 4..n / 32 {
        acc = mix32(acc, b, 32 * i, 32 * i + 16, MIDSIZE_SECRET + 32 * (i - 4));
    }
    acc = mix32(acc, b, n - 16, n - 32, MIDSIZE_LAST_SECRET - 16);
    fin128(acc.0, acc.1, n as u64)
}

// ---- long path (len > 240) ----

const ACC_INIT: [u64; 8] = [P32_3, P64_1, P64_2, P64_3, P64_4, P32_2, P64_5, P32_1];

/// `XXH3_accumulate_512`: fold one 64-byte stripe into the accumulators
/// using the secret slice starting at `soff`.
#[inline]
fn accumulate(acc: &mut [u64; 8], stripe: &[u8], soff: usize) {
    for i in 0..8 {
        let dv = r64(stripe, 8 * i);
        let dk = dv ^ r64(&SECRET, soff + 8 * i);
        acc[i ^ 1] = acc[i ^ 1].wrapping_add(dv);
        acc[i] = acc[i].wrapping_add((dk & 0xFFFF_FFFF).wrapping_mul(dk >> 32));
    }
}

/// `XXH3_scrambleAcc`: re-randomize the accumulators at block boundaries.
#[inline]
fn scramble(acc: &mut [u64; 8]) {
    for (i, a) in acc.iter_mut().enumerate() {
        let mut v = xorshift(*a, 47);
        v ^= r64(&SECRET, 128 + 8 * i);
        *a = v.wrapping_mul(P32_1);
    }
}

/// One full stripe, advancing the in-block counter and scrambling at the
/// block boundary. Free function so callers can borrow `buf` alongside.
#[inline]
fn stripe(acc: &mut [u64; 8], in_block: &mut usize, input: &[u8]) {
    accumulate(acc, input, 8 * *in_block);
    *in_block += 1;
    if *in_block == STRIPES_PER_BLOCK {
        scramble(acc);
        *in_block = 0;
    }
}

/// `XXH3_mergeAccs` over the four accumulator pairs.
fn merge(acc: &[u64; 8], soff: usize, start: u64) -> u64 {
    let mut r = start;
    for i in 0..4 {
        r = r.wrapping_add(fold64(
            acc[2 * i] ^ r64(&SECRET, soff + 16 * i),
            acc[2 * i + 1] ^ r64(&SECRET, soff + 16 * i + 8),
        ));
    }
    avalanche(r)
}

/// Shared streaming core for both output widths.
///
/// Invariant: while `total <= 240` every byte seen so far sits in `buf`
/// (short paths need the whole input). Beyond 240 bytes, stripes are
/// consumed greedily but the last 1..=64 bytes always stay buffered so the
/// stripe/scramble schedule matches the one-shot reference; `last64`
/// tracks the trailing 64 bytes of the whole stream for the final stripe.
#[derive(Clone)]
struct Core {
    buf: Vec<u8>,
    total: u64,
    acc: [u64; 8],
    in_block: usize,
    last64: [u8; 64],
}

impl Core {
    fn new() -> Core {
        Core { buf: Vec::new(), total: 0, acc: ACC_INIT, in_block: 0, last64: [0u8; 64] }
    }

    fn reset(&mut self) {
        self.buf.clear();
        self.total = 0;
        self.acc = ACC_INIT;
        self.in_block = 0;
    }

    fn update(&mut self, data: &[u8]) {
        self.total += data.len() as u64;
        if self.total > 240 && self.buf.is_empty() && data.len() >= 65 {
            // Fast path (typical one-shot leaf): stripe straight from the
            // caller's slice, buffering only the 1..=64-byte tail.
            let consumable = (data.len() - 1) / 64 * 64;
            let mut off = 0;
            while off < consumable {
                stripe(&mut self.acc, &mut self.in_block, &data[off..off + 64]);
                off += 64;
            }
            self.buf.extend_from_slice(&data[consumable..]);
        } else {
            self.buf.extend_from_slice(data);
            if self.total > 240 && self.buf.len() >= 65 {
                let n = self.buf.len();
                let consumable = (n - 1) / 64 * 64;
                let mut off = 0;
                while off < consumable {
                    stripe(&mut self.acc, &mut self.in_block, &self.buf[off..off + 64]);
                    off += 64;
                }
                self.buf.copy_within(consumable.., 0);
                self.buf.truncate(n - consumable);
            }
        }
        if data.len() >= 64 {
            self.last64.copy_from_slice(&data[data.len() - 64..]);
        } else if !data.is_empty() {
            let k = data.len();
            self.last64.copy_within(k.., 0);
            self.last64[64 - k..].copy_from_slice(data);
        }
    }

    /// Long-path finalization: the last stripe over the trailing 64 bytes
    /// with the dedicated secret offset, then the merge(s).
    fn long_digest(&self, wide: bool) -> (u64, u64) {
        debug_assert!(self.total > 240);
        let mut acc = self.acc;
        accumulate(&mut acc, &self.last64, LAST_STRIPE_SECRET);
        let lo = merge(&acc, MERGE_SECRET_LO, self.total.wrapping_mul(P64_1));
        if !wide {
            return (lo, 0);
        }
        let hi = merge(&acc, MERGE_SECRET_HI, !(self.total.wrapping_mul(P64_2)));
        (lo, hi)
    }

    fn digest64(&self) -> u64 {
        if self.total <= 240 {
            let b = &self.buf[..];
            match b.len() {
                0..=16 => len_0to16_64(b),
                17..=128 => len_17to128_64(b),
                _ => len_129to240_64(b),
            }
        } else {
            self.long_digest(false).0
        }
    }

    fn digest128(&self) -> (u64, u64) {
        if self.total <= 240 {
            let b = &self.buf[..];
            match b.len() {
                0..=16 => len_0to16_128(b),
                17..=128 => len_17to128_128(b),
                _ => len_129to240_128(b),
            }
        } else {
            self.long_digest(true)
        }
    }
}

/// Streaming XXH3-64 (8-byte digest, canonical big-endian output).
#[derive(Clone)]
pub struct Xxh364 {
    core: Core,
}

impl Xxh364 {
    /// Fresh hasher (seed 0, default secret).
    pub fn new() -> Xxh364 {
        Xxh364 { core: Core::new() }
    }
}

impl Default for Xxh364 {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher for Xxh364 {
    fn update(&mut self, data: &[u8]) {
        self.core.update(data);
    }

    fn finalize(&mut self) -> Vec<u8> {
        self.core.digest64().to_be_bytes().to_vec()
    }

    fn digest_len(&self) -> usize {
        8
    }

    fn reset(&mut self) {
        self.core.reset();
    }
}

/// Streaming XXH3-128 (16-byte digest, canonical big-endian output).
#[derive(Clone)]
pub struct Xxh3128 {
    core: Core,
}

impl Xxh3128 {
    /// Fresh hasher (seed 0, default secret).
    pub fn new() -> Xxh3128 {
        Xxh3128 { core: Core::new() }
    }
}

impl Default for Xxh3128 {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher for Xxh3128 {
    fn update(&mut self, data: &[u8]) {
        self.core.update(data);
    }

    fn finalize(&mut self) -> Vec<u8> {
        let (lo, hi) = self.core.digest128();
        let v = ((hi as u128) << 64) | lo as u128;
        v.to_be_bytes().to_vec()
    }

    fn digest_len(&self) -> usize {
        16
    }

    fn reset(&mut self) {
        self.core.reset();
    }
}

/// One-shot XXH3-64 of a byte slice.
pub fn xxh3_64(data: &[u8]) -> u64 {
    let mut c = Core::new();
    c.update(data);
    c.digest64()
}

/// One-shot XXH3-128 of a byte slice (canonical value: high half in the
/// upper 64 bits).
pub fn xxh3_128(data: &[u8]) -> u128 {
    let mut c = Core::new();
    c.update(data);
    let (lo, hi) = c.digest128();
    ((hi as u128) << 64) | lo as u128
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    /// Deterministic test pattern, independent of input length.
    fn pat(n: usize) -> Vec<u8> {
        (0..n).map(|i| ((i * 131 + 7) & 0xff) as u8).collect()
    }

    /// Reference vectors generated with python-xxhash 3.6.0 (the C
    /// reference implementation) over `pat(n)`: (len, xxh3_64, xxh3_128).
    const VECTORS: &[(usize, &str, &str)] = &[
        (0, "2d06800538d394c2", "99aa06d3014798d86001c324468d497f"),
        (1, "4c5cca45d0f4811f", "495b62073ef70ca44c5cca45d0f4811f"),
        (2, "29c60963cbfa4e6e", "f1b5eec902a1eb5e29c60963cbfa4e6e"),
        (3, "6e3e2670e61106ac", "390cdc5b4a895dd76e3e2670e61106ac"),
        (4, "5c4c63133443d03f", "aa6e2f274640a3f43d668af6f2a44d77"),
        (6, "71655f8cab99dd4e", "6003580bfd3c45e9536f7a3ebed5ff6f"),
        (8, "f9fd4dd0b04d78f5", "6a86a3bda6af4e3d61ddbe7f31a6100d"),
        (9, "7c20df9712c26edf", "664c7ca18afd62558c7b67fd458a936b"),
        (12, "16d2dff54dc2ee45", "dab57051afe30b1dcdeba3d6707f8f04"),
        (16, "86abf6baccea0858", "7f9a218b0425449ae2ce54a7c19c730d"),
        (17, "b58bf5dc5022d071", "66fc23f6439dbd778d96ef110fcdebb4"),
        (32, "e3712ed84c04a66e", "49a11ee743d6d342fd357cf6cb2dda18"),
        (63, "30ca01f63dcc223b", "943c9c8db76d06239ede94f828604a13"),
        (64, "1291d2d4042330dd", "e0faf20e0e0fe0ddba7e015a54f14be1"),
        (96, "81296929fc063365", "fb78ac185ef554438b8720f565dcf40c"),
        (100, "5da67eac6d4093d5", "76b536586de98b82580b061a98a5a9b4"),
        (128, "10d17f72c0ccba41", "aec730751478556cff361dec1385710a"),
        (129, "1648bdc3db49d1a2", "98cd36ccbb5579264545b3a09738e31a"),
        (130, "c65f0f545fa96def", "7fa91940f13fed8f51f93bd2e6f2a3cb"),
        (163, "a171128849a1676f", "699f85f564d11fafcd25509fe8f6209e"),
        (192, "daf64f63dc7d5e36", "e9e3bb05b10df5c44079b989e727fb44"),
        (240, "b6cfaf343fab81e6", "5293e17bf553903d3f2c53e72293711f"),
        (241, "956cae592c67279e", "b53840fe3fedf161956cae592c67279e"),
        (256, "b15e550733c5dfac", "d0d2829a226d0edbb15e550733c5dfac"),
        (511, "5a17da924907228a", "b3324be14e173e725a17da924907228a"),
        (512, "a0e9790eb93990d7", "7509d702d4519576a0e9790eb93990d7"),
        (1023, "a94ffcd2254368e4", "0990de11f2b13621a94ffcd2254368e4"),
        (1024, "70bd377d9574f4bb", "f69630613f24324d70bd377d9574f4bb"),
        (1025, "66c4487c41e127a7", "621af7b8277effa466c4487c41e127a7"),
        (2048, "8b46caa67dab3a30", "56b77f207158a2ba8b46caa67dab3a30"),
        (4096, "9ddd66c14af0daff", "3e0ff38fa88a55ea9ddd66c14af0daff"),
        (65536, "04404b28125b4786", "ed19e9be90ac5adc04404b28125b4786"),
        (100000, "14ce8d6fc2c4868b", "e9e46da59b77e42314ce8d6fc2c4868b"),
    ];

    #[test]
    fn reference_vectors_oneshot() {
        for &(n, h64, h128) in VECTORS {
            let data = pat(n);
            assert_eq!(hex::encode(&xxh3_64(&data).to_be_bytes()), h64, "xxh3-64 len {n}");
            assert_eq!(hex::encode(&xxh3_128(&data).to_be_bytes()), h128, "xxh3-128 len {n}");
        }
    }

    #[test]
    fn reference_vectors_streaming() {
        // Chunk sizes chosen to cross every internal boundary: sub-stripe,
        // stripe, short/long threshold, block.
        for chunk in [1usize, 3, 37, 63, 64, 65, 240, 241, 1000] {
            for &(n, h64, h128) in VECTORS {
                let data = pat(n);
                let mut a = Xxh364::new();
                let mut b = Xxh3128::new();
                for part in data.chunks(chunk) {
                    a.update(part);
                    b.update(part);
                }
                assert_eq!(hex::encode(&a.finalize()), h64, "xxh3-64 len {n} chunk {chunk}");
                assert_eq!(hex::encode(&b.finalize()), h128, "xxh3-128 len {n} chunk {chunk}");
            }
        }
    }

    #[test]
    fn known_ascii_vectors() {
        assert_eq!(xxh3_64(b""), 0x2d06800538d394c2);
        assert_eq!(xxh3_64(b"abc"), 0x78af5f94892f3950);
        assert_eq!(xxh3_128(b""), 0x99aa06d3014798d86001c324468d497f);
        assert_eq!(xxh3_128(b"abc"), 0x06b05ab6733a618578af5f94892f3950);
    }

    #[test]
    fn long_path_low_half_matches_xxh3_64() {
        // Structural property of the spec: beyond 240 bytes the 128-bit
        // digest's low half is exactly the 64-bit digest.
        for n in [241usize, 1024, 1025, 4096, 100000] {
            let data = pat(n);
            assert_eq!(xxh3_128(&data) as u64, xxh3_64(&data), "len {n}");
        }
    }

    #[test]
    fn reset_clears_all_state() {
        let mut h = Xxh3128::new();
        h.update(&pat(100000));
        let _ = h.finalize();
        h.reset();
        h.update(b"abc");
        assert_eq!(hex::encode(&h.finalize()), format!("{:032x}", xxh3_128(b"abc")));
    }
}
