//! From-scratch hash implementations (no crypto crates offline — and the
//! paper's hash comparison, Fig 10, requires MD5/SHA-1/SHA-256 anyway).
//!
//! * [`md5`] — RFC 1321
//! * [`sha1`] — RFC 3174
//! * [`sha256`] — FIPS 180-4 / RFC 6234
//! * [`fvr256`] — native port of the FVR-256 block-parallel hash whose
//!   normative definition is the Pallas kernel in
//!   `python/compile/kernels/fvr_hash.py` (bit-exact; verified against
//!   `artifacts/test_vectors.json`)
//! * [`xxh3`] — XXH3-64/128, the non-cryptographic *fast tier* for leaf
//!   and transport digests (canonical big-endian output, verified against
//!   the reference implementation's vectors)
//!
//! All implement [`Hasher`]; [`HashAlgorithm`] is the runtime-selectable
//! registry the coordinator and CLI use, and [`HashTier`] selects how the
//! fast and cryptographic families are composed (see DESIGN.md, "Tiered
//! hashing").

/// FVR-256: the 8-lane verification digest.
pub mod fvr256;
/// MD5 (RFC 1321), from scratch.
pub mod md5;
/// SHA-1 (FIPS 180-4), from scratch.
pub mod sha1;
/// SHA-256 (FIPS 180-4), from scratch.
pub mod sha256;
/// XXH3-64/128: the non-cryptographic fast tier.
pub mod xxh3;

/// Factory producing fresh streaming hashers; shared across threads. The
/// single definition behind [`crate::coordinator::HasherFactory`] and
/// [`crate::merkle::DigestFactory`].
pub type DigestFactory = std::sync::Arc<dyn Fn() -> Box<dyn Hasher> + Send + Sync>;

/// Streaming hash interface (mirrors `MessageDigest` in the paper's
/// Algorithms 1 & 2: `update()` in the queue-consumer loop, `digest()` at
/// file end).
pub trait Hasher: Send {
    /// Absorb a buffer.
    fn update(&mut self, data: &[u8]);
    /// Finalize and return the digest bytes. Consumes logical state; the
    /// hasher must not be updated afterwards.
    fn finalize(&mut self) -> Vec<u8>;
    /// Digest length in bytes.
    fn digest_len(&self) -> usize;
    /// Reset to the initial state for reuse on the next file/chunk.
    fn reset(&mut self);
}

/// Hash algorithm selector (Fig 10 compares MD5 / SHA-1 / SHA-256; FVR-256
/// is our TPU-adapted hash, in XLA-artifact or native form).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HashAlgorithm {
    /// MD5 (128-bit).
    Md5,
    /// SHA-1 (160-bit).
    Sha1,
    /// SHA-256 (256-bit).
    Sha256,
    /// FVR-256 (256-bit, 8 lanes).
    Fvr256,
    /// XXH3-64 (64-bit, non-cryptographic fast tier).
    Xxh364,
    /// XXH3-128 (128-bit, non-cryptographic fast tier).
    Xxh3128,
}

impl HashAlgorithm {
    /// Every hash backend, in registry order — the single source of truth
    /// for tests, benches, experiment drivers and CLI help.
    pub const ALL: [HashAlgorithm; 6] = [
        HashAlgorithm::Md5,
        HashAlgorithm::Sha1,
        HashAlgorithm::Sha256,
        HashAlgorithm::Fvr256,
        HashAlgorithm::Xxh364,
        HashAlgorithm::Xxh3128,
    ];

    /// Canonical display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            HashAlgorithm::Md5 => "md5",
            HashAlgorithm::Sha1 => "sha1",
            HashAlgorithm::Sha256 => "sha256",
            HashAlgorithm::Fvr256 => "fvr256",
            HashAlgorithm::Xxh364 => "xxh3-64",
            HashAlgorithm::Xxh3128 => "xxh3-128",
        }
    }

    /// Parse a CLI hash name.
    pub fn parse(s: &str) -> Option<HashAlgorithm> {
        match s.to_ascii_lowercase().as_str() {
            "md5" => Some(HashAlgorithm::Md5),
            "sha1" | "sha-1" => Some(HashAlgorithm::Sha1),
            "sha256" | "sha-256" => Some(HashAlgorithm::Sha256),
            "fvr256" | "fvr-256" | "fvr" => Some(HashAlgorithm::Fvr256),
            "xxh3-64" | "xxh3_64" | "xxh64" => Some(HashAlgorithm::Xxh364),
            "xxh3-128" | "xxh3_128" | "xxh128" | "xxh3" => Some(HashAlgorithm::Xxh3128),
            _ => None,
        }
    }

    /// Instantiate a streaming hasher.
    pub fn hasher(&self) -> Box<dyn Hasher> {
        match self {
            HashAlgorithm::Md5 => Box::new(md5::Md5::new()),
            HashAlgorithm::Sha1 => Box::new(sha1::Sha1::new()),
            HashAlgorithm::Sha256 => Box::new(sha256::Sha256::new()),
            HashAlgorithm::Fvr256 => Box::new(fvr256::Fvr256::default()),
            HashAlgorithm::Xxh364 => Box::new(xxh3::Xxh364::new()),
            HashAlgorithm::Xxh3128 => Box::new(xxh3::Xxh3128::new()),
        }
    }

    /// True for the non-cryptographic fast-tier hashes: fine against
    /// random corruption, useless against an adversary who can choose the
    /// corruption (see the tiered-hashing threat model in DESIGN.md).
    pub fn is_fast_tier(&self) -> bool {
        matches!(self, HashAlgorithm::Xxh364 | HashAlgorithm::Xxh3128)
    }

    /// Relative checksum cost vs MD5, from the paper's Fig 10 measurements
    /// (checksum-only on the ESNet mixed dataset: MD5 476 s, SHA1 713 s,
    /// SHA256 1043 s). Used by the simulator to scale hash-core rates.
    /// FVR-256's block-parallel structure hashes at roughly memory speed on
    /// wide-vector hardware; we conservatively model it at MD5 cost on CPU.
    /// XXH3 is a multiply-fold sponge with no cryptographic rounds and runs
    /// an order of magnitude faster than MD5 even scalar (the whole point
    /// of the fast tier); 0.05 ≈ the ~20x gap the xxHash reference
    /// benchmarks report for large inputs.
    pub fn relative_cost(&self) -> f64 {
        match self {
            HashAlgorithm::Md5 => 1.0,
            HashAlgorithm::Sha1 => 713.0 / 476.0,
            HashAlgorithm::Sha256 => 1043.0 / 476.0,
            HashAlgorithm::Fvr256 => 1.0,
            HashAlgorithm::Xxh364 => 0.05,
            HashAlgorithm::Xxh3128 => 0.05,
        }
    }

    /// `"md5|sha1|sha256|fvr256|xxh3-64|xxh3-128"` — for CLI usage strings.
    pub fn names_joined() -> String {
        Self::ALL.map(|a| a.name()).join("|")
    }
}

/// How the fast and cryptographic hash families compose into the session's
/// integrity plane (CLI `--hash-tier`, env `FIVER_HASH_TIER`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum HashTier {
    /// Everything — leaves, units, roots — uses the fast tier (XXH3-128).
    /// Fastest, but no cryptographic anchor anywhere: detects random
    /// corruption only.
    Fast,
    /// Everything uses the session's cryptographic [`HashAlgorithm`]
    /// (`--hash`). The pre-tiering behavior and the default.
    #[default]
    Cryptographic,
    /// Leaf/unit/transport digests use XXH3-128; Merkle interior nodes and
    /// roots use the cryptographic algorithm (BLAKE3-style composition:
    /// fast leaves under a crypto root, end-to-end trust unchanged for
    /// tree-verified transfers).
    Tiered,
}

impl HashTier {
    /// Every tier, in registry order.
    pub const ALL: [HashTier; 3] = [HashTier::Fast, HashTier::Cryptographic, HashTier::Tiered];

    /// Canonical display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            HashTier::Fast => "fast",
            HashTier::Cryptographic => "cryptographic",
            HashTier::Tiered => "tiered",
        }
    }

    /// Parse a CLI/env tier name.
    pub fn parse(s: &str) -> Option<HashTier> {
        match s.to_ascii_lowercase().as_str() {
            "fast" | "xxh3" => Some(HashTier::Fast),
            "cryptographic" | "crypto" => Some(HashTier::Cryptographic),
            "tiered" | "tier" => Some(HashTier::Tiered),
            _ => None,
        }
    }

    /// Tier from `FIVER_HASH_TIER` (the CI matrix lever), defaulting to
    /// [`HashTier::Cryptographic`]. Unknown values fall back to the
    /// default rather than erroring, mirroring `IoBackend::from_env`.
    pub fn from_env() -> HashTier {
        std::env::var("FIVER_HASH_TIER")
            .ok()
            .and_then(|v| HashTier::parse(&v))
            .unwrap_or_default()
    }

    /// `"fast|cryptographic|tiered"` — for CLI usage strings.
    pub fn names_joined() -> String {
        Self::ALL.map(|t| t.name()).join("|")
    }
}

/// One-shot convenience: hash a byte slice to hex.
pub fn hex_digest(alg: HashAlgorithm, data: &[u8]) -> String {
    let mut h = alg.hasher();
    h.update(data);
    crate::util::hex::encode(&h.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_roundtrip() {
        for alg in HashAlgorithm::ALL {
            assert_eq!(HashAlgorithm::parse(alg.name()), Some(alg));
        }
        assert_eq!(HashAlgorithm::parse("nope"), None);
        assert_eq!(HashAlgorithm::names_joined(), "md5|sha1|sha256|fvr256|xxh3-64|xxh3-128");
    }

    #[test]
    fn digest_lengths() {
        assert_eq!(HashAlgorithm::Md5.hasher().digest_len(), 16);
        assert_eq!(HashAlgorithm::Sha1.hasher().digest_len(), 20);
        assert_eq!(HashAlgorithm::Sha256.hasher().digest_len(), 32);
        assert_eq!(HashAlgorithm::Fvr256.hasher().digest_len(), 32);
        assert_eq!(HashAlgorithm::Xxh364.hasher().digest_len(), 8);
        assert_eq!(HashAlgorithm::Xxh3128.hasher().digest_len(), 16);
    }

    #[test]
    fn relative_costs_ordered() {
        assert!(HashAlgorithm::Md5.relative_cost() < HashAlgorithm::Sha1.relative_cost());
        assert!(HashAlgorithm::Sha1.relative_cost() < HashAlgorithm::Sha256.relative_cost());
        // The fast tier must be meaningfully cheaper than every
        // cryptographic backend, or tiering would be pointless.
        for alg in HashAlgorithm::ALL {
            if !alg.is_fast_tier() {
                assert!(
                    HashAlgorithm::Xxh3128.relative_cost() < alg.relative_cost() / 2.0,
                    "{}",
                    alg.name()
                );
            }
        }
    }

    #[test]
    fn tier_registry_roundtrip() {
        for tier in HashTier::ALL {
            assert_eq!(HashTier::parse(tier.name()), Some(tier));
        }
        assert_eq!(HashTier::parse("nope"), None);
        assert_eq!(HashTier::default(), HashTier::Cryptographic);
        assert_eq!(HashTier::names_joined(), "fast|cryptographic|tiered");
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data: Vec<u8> = (0..=255).cycle().take(1000).collect();
        for alg in HashAlgorithm::ALL {
            let oneshot = hex_digest(alg, &data);
            let mut h = alg.hasher();
            for part in data.chunks(37) {
                h.update(part);
            }
            assert_eq!(crate::util::hex::encode(&h.finalize()), oneshot, "{}", alg.name());
        }
    }

    #[test]
    fn reset_reuses_cleanly() {
        for alg in HashAlgorithm::ALL {
            let mut h = alg.hasher();
            h.update(b"garbage");
            let _ = h.finalize();
            h.reset();
            h.update(b"abc");
            let fresh = hex_digest(alg, b"abc");
            assert_eq!(crate::util::hex::encode(&h.finalize()), fresh, "{}", alg.name());
        }
    }
}
