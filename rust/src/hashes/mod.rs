//! From-scratch hash implementations (no crypto crates offline — and the
//! paper's hash comparison, Fig 10, requires MD5/SHA-1/SHA-256 anyway).
//!
//! * [`md5`] — RFC 1321
//! * [`sha1`] — RFC 3174
//! * [`sha256`] — FIPS 180-4 / RFC 6234
//! * [`fvr256`] — native port of the FVR-256 block-parallel hash whose
//!   normative definition is the Pallas kernel in
//!   `python/compile/kernels/fvr_hash.py` (bit-exact; verified against
//!   `artifacts/test_vectors.json`)
//!
//! All implement [`Hasher`]; [`HashAlgorithm`] is the runtime-selectable
//! registry the coordinator and CLI use.

/// FVR-256: the 8-lane verification digest.
pub mod fvr256;
/// MD5 (RFC 1321), from scratch.
pub mod md5;
/// SHA-1 (FIPS 180-4), from scratch.
pub mod sha1;
/// SHA-256 (FIPS 180-4), from scratch.
pub mod sha256;

/// Factory producing fresh streaming hashers; shared across threads. The
/// single definition behind [`crate::coordinator::HasherFactory`] and
/// [`crate::merkle::DigestFactory`].
pub type DigestFactory = std::sync::Arc<dyn Fn() -> Box<dyn Hasher> + Send + Sync>;

/// Streaming hash interface (mirrors `MessageDigest` in the paper's
/// Algorithms 1 & 2: `update()` in the queue-consumer loop, `digest()` at
/// file end).
pub trait Hasher: Send {
    /// Absorb a buffer.
    fn update(&mut self, data: &[u8]);
    /// Finalize and return the digest bytes. Consumes logical state; the
    /// hasher must not be updated afterwards.
    fn finalize(&mut self) -> Vec<u8>;
    /// Digest length in bytes.
    fn digest_len(&self) -> usize;
    /// Reset to the initial state for reuse on the next file/chunk.
    fn reset(&mut self);
}

/// Hash algorithm selector (Fig 10 compares MD5 / SHA-1 / SHA-256; FVR-256
/// is our TPU-adapted hash, in XLA-artifact or native form).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HashAlgorithm {
    /// MD5 (128-bit).
    Md5,
    /// SHA-1 (160-bit).
    Sha1,
    /// SHA-256 (256-bit).
    Sha256,
    /// FVR-256 (256-bit, 8 lanes).
    Fvr256,
}

impl HashAlgorithm {
    /// Every hash backend, in registry order — the single source of truth
    /// for tests, benches, experiment drivers and CLI help.
    pub const ALL: [HashAlgorithm; 4] =
        [HashAlgorithm::Md5, HashAlgorithm::Sha1, HashAlgorithm::Sha256, HashAlgorithm::Fvr256];

    /// Canonical display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            HashAlgorithm::Md5 => "md5",
            HashAlgorithm::Sha1 => "sha1",
            HashAlgorithm::Sha256 => "sha256",
            HashAlgorithm::Fvr256 => "fvr256",
        }
    }

    /// Parse a CLI hash name.
    pub fn parse(s: &str) -> Option<HashAlgorithm> {
        match s.to_ascii_lowercase().as_str() {
            "md5" => Some(HashAlgorithm::Md5),
            "sha1" | "sha-1" => Some(HashAlgorithm::Sha1),
            "sha256" | "sha-256" => Some(HashAlgorithm::Sha256),
            "fvr256" | "fvr-256" | "fvr" => Some(HashAlgorithm::Fvr256),
            _ => None,
        }
    }

    /// Instantiate a streaming hasher.
    pub fn hasher(&self) -> Box<dyn Hasher> {
        match self {
            HashAlgorithm::Md5 => Box::new(md5::Md5::new()),
            HashAlgorithm::Sha1 => Box::new(sha1::Sha1::new()),
            HashAlgorithm::Sha256 => Box::new(sha256::Sha256::new()),
            HashAlgorithm::Fvr256 => Box::new(fvr256::Fvr256::default()),
        }
    }

    /// Relative checksum cost vs MD5, from the paper's Fig 10 measurements
    /// (checksum-only on the ESNet mixed dataset: MD5 476 s, SHA1 713 s,
    /// SHA256 1043 s). Used by the simulator to scale hash-core rates.
    /// FVR-256's block-parallel structure hashes at roughly memory speed on
    /// wide-vector hardware; we conservatively model it at MD5 cost on CPU.
    pub fn relative_cost(&self) -> f64 {
        match self {
            HashAlgorithm::Md5 => 1.0,
            HashAlgorithm::Sha1 => 713.0 / 476.0,
            HashAlgorithm::Sha256 => 1043.0 / 476.0,
            HashAlgorithm::Fvr256 => 1.0,
        }
    }

    /// `"md5|sha1|sha256|fvr256"` — for CLI usage strings.
    pub fn names_joined() -> String {
        Self::ALL.map(|a| a.name()).join("|")
    }
}

/// One-shot convenience: hash a byte slice to hex.
pub fn hex_digest(alg: HashAlgorithm, data: &[u8]) -> String {
    let mut h = alg.hasher();
    h.update(data);
    crate::util::hex::encode(&h.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_roundtrip() {
        for alg in HashAlgorithm::ALL {
            assert_eq!(HashAlgorithm::parse(alg.name()), Some(alg));
        }
        assert_eq!(HashAlgorithm::parse("nope"), None);
        assert_eq!(HashAlgorithm::names_joined(), "md5|sha1|sha256|fvr256");
    }

    #[test]
    fn digest_lengths() {
        assert_eq!(HashAlgorithm::Md5.hasher().digest_len(), 16);
        assert_eq!(HashAlgorithm::Sha1.hasher().digest_len(), 20);
        assert_eq!(HashAlgorithm::Sha256.hasher().digest_len(), 32);
        assert_eq!(HashAlgorithm::Fvr256.hasher().digest_len(), 32);
    }

    #[test]
    fn relative_costs_ordered() {
        assert!(HashAlgorithm::Md5.relative_cost() < HashAlgorithm::Sha1.relative_cost());
        assert!(HashAlgorithm::Sha1.relative_cost() < HashAlgorithm::Sha256.relative_cost());
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data: Vec<u8> = (0..=255).cycle().take(1000).collect();
        for alg in HashAlgorithm::ALL {
            let oneshot = hex_digest(alg, &data);
            let mut h = alg.hasher();
            for part in data.chunks(37) {
                h.update(part);
            }
            assert_eq!(crate::util::hex::encode(&h.finalize()), oneshot, "{}", alg.name());
        }
    }

    #[test]
    fn reset_reuses_cleanly() {
        for alg in HashAlgorithm::ALL {
            let mut h = alg.hasher();
            h.update(b"garbage");
            let _ = h.finalize();
            h.reset();
            h.update(b"abc");
            let fresh = hex_digest(alg, b"abc");
            assert_eq!(crate::util::hex::encode(&h.finalize()), fresh, "{}", alg.name());
        }
    }
}
