//! MD5 (RFC 1321), implemented from scratch.
//!
//! Used as the paper's default hash (its testbeds hash MD5 at ~3 Gbps/core,
//! which is the asymmetry FIVER exploits). Verified against the RFC 1321
//! appendix test suite.

use super::Hasher;

const S: [u32; 64] = [
    7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22,
    5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20,
    4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23,
    6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21,
];

// K[i] = floor(2^32 * abs(sin(i + 1)))
const K: [u32; 64] = [
    0xd76aa478, 0xe8c7b756, 0x242070db, 0xc1bdceee, 0xf57c0faf, 0x4787c62a,
    0xa8304613, 0xfd469501, 0x698098d8, 0x8b44f7af, 0xffff5bb1, 0x895cd7be,
    0x6b901122, 0xfd987193, 0xa679438e, 0x49b40821, 0xf61e2562, 0xc040b340,
    0x265e5a51, 0xe9b6c7aa, 0xd62f105d, 0x02441453, 0xd8a1e681, 0xe7d3fbc8,
    0x21e1cde6, 0xc33707d6, 0xf4d50d87, 0x455a14ed, 0xa9e3e905, 0xfcefa3f8,
    0x676f02d9, 0x8d2a4c8a, 0xfffa3942, 0x8771f681, 0x6d9d6122, 0xfde5380c,
    0xa4beea44, 0x4bdecfa9, 0xf6bb4b60, 0xbebfbc70, 0x289b7ec6, 0xeaa127fa,
    0xd4ef3085, 0x04881d05, 0xd9d4d039, 0xe6db99e5, 0x1fa27cf8, 0xc4ac5665,
    0xf4292244, 0x432aff97, 0xab9423a7, 0xfc93a039, 0x655b59c3, 0x8f0ccc92,
    0xffeff47d, 0x85845dd1, 0x6fa87e4f, 0xfe2ce6e0, 0xa3014314, 0x4e0811a1,
    0xf7537e82, 0xbd3af235, 0x2ad7d2bb, 0xeb86d391,
];

const INIT: [u32; 4] = [0x67452301, 0xefcdab89, 0x98badcfe, 0x10325476];

/// Streaming MD5 state.
pub struct Md5 {
    state: [u32; 4],
    /// Bytes processed so far (mod 2^64), for length padding.
    len: u64,
    buf: [u8; 64],
    buf_len: usize,
}

impl Default for Md5 {
    fn default() -> Self {
        Self::new()
    }
}

impl Md5 {
    /// A fresh hasher.
    pub fn new() -> Self {
        Md5 { state: INIT, len: 0, buf: [0; 64], buf_len: 0 }
    }

    fn compress(state: &mut [u32; 4], block: &[u8; 64]) {
        let mut m = [0u32; 16];
        for (i, w) in m.iter_mut().enumerate() {
            *w = u32::from_le_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
        }
        let [mut a, mut b, mut c, mut d] = *state;
        for i in 0..64 {
            let (f, g) = match i / 16 {
                0 => ((b & c) | (!b & d), i),
                1 => ((d & b) | (!d & c), (5 * i + 1) % 16),
                2 => (b ^ c ^ d, (3 * i + 5) % 16),
                _ => (c ^ (b | !d), (7 * i) % 16),
            };
            let tmp = d;
            d = c;
            c = b;
            b = b.wrapping_add(
                a.wrapping_add(f).wrapping_add(K[i]).wrapping_add(m[g]).rotate_left(S[i]),
            );
            a = tmp;
        }
        state[0] = state[0].wrapping_add(a);
        state[1] = state[1].wrapping_add(b);
        state[2] = state[2].wrapping_add(c);
        state[3] = state[3].wrapping_add(d);
    }
}

impl Hasher for Md5 {
    fn update(&mut self, mut data: &[u8]) {
        self.len = self.len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len < 64 {
                return; // staged only; nothing else to process
            }
            let block = self.buf;
            Self::compress(&mut self.state, &block);
            self.buf_len = 0;
        }
        let mut chunks = data.chunks_exact(64);
        for block in &mut chunks {
            Self::compress(&mut self.state, block.try_into().unwrap());
        }
        let rem = chunks.remainder();
        self.buf[..rem.len()].copy_from_slice(rem);
        self.buf_len = rem.len();
    }

    fn finalize(&mut self) -> Vec<u8> {
        let bit_len = self.len.wrapping_mul(8);
        // Padding: 0x80, zeros, 8-byte little-endian bit length.
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Manual final block write: don't count padding length bytes twice.
        self.buf[56..64].copy_from_slice(&bit_len.to_le_bytes());
        let block = self.buf;
        Self::compress(&mut self.state, &block);
        self.buf_len = 0;
        self.state.iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    fn digest_len(&self) -> usize {
        16
    }

    fn reset(&mut self) {
        *self = Md5::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashes::Hasher;
    use crate::util::hex;

    fn md5_hex(data: &[u8]) -> String {
        let mut h = Md5::new();
        h.update(data);
        hex::encode(&h.finalize())
    }

    /// RFC 1321 appendix A.5 test suite.
    #[test]
    fn rfc1321_vectors() {
        assert_eq!(md5_hex(b""), "d41d8cd98f00b204e9800998ecf8427e");
        assert_eq!(md5_hex(b"a"), "0cc175b9c0f1b6a831c399e269772661");
        assert_eq!(md5_hex(b"abc"), "900150983cd24fb0d6963f7d28e17f72");
        assert_eq!(md5_hex(b"message digest"), "f96b697d7cb7938d525a2f31aaf161d0");
        assert_eq!(
            md5_hex(b"abcdefghijklmnopqrstuvwxyz"),
            "c3fcd3d76192e4007dfb496cca67e13b"
        );
        assert_eq!(
            md5_hex(b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789"),
            "d174ab98d277d9f5a5611c2c9f419d9f"
        );
        assert_eq!(
            md5_hex(
                b"12345678901234567890123456789012345678901234567890123456789012345678901234567890"
            ),
            "57edf4a22be3c955ac49da2e2107b67a"
        );
    }

    #[test]
    fn block_boundary_lengths() {
        // 55/56/57/63/64/65 bytes probe the padding edge cases.
        for n in [55usize, 56, 57, 63, 64, 65, 127, 128, 129] {
            let data = vec![0x61u8; n];
            let whole = md5_hex(&data);
            let mut h = Md5::new();
            h.update(&data[..n / 2]);
            h.update(&data[n / 2..]);
            assert_eq!(hex::encode(&h.finalize()), whole, "len {n}");
        }
    }

    #[test]
    fn one_million_a() {
        let mut h = Md5::new();
        let chunk = [0x61u8; 1000];
        for _ in 0..1000 {
            h.update(&chunk);
        }
        assert_eq!(hex::encode(&h.finalize()), "7707d6ae4e027c70eea2a935c2296f21");
    }
}
