//! Testbed configurations (the paper's Tables I and II) and algorithm
//! parameters.
//!
//! Rates are calibrated from the paper's own reported numbers rather than
//! the hardware nameplates, because the paper's analysis depends on the
//! *achieved* rates (e.g. "disk I/O is limited to 5-6 Gbps" on ESNet's
//! 100 Gbps NICs; MD5 at ~3 Gbps/core):
//!
//! * ESNet: 100 G file transferred in 140 s → 5.7 Gbps effective path;
//!   checksum of the same file 273 s → 2.93 Gbps MD5.
//! * HPCLab-1G: 1 Gbps link is the bottleneck; a desktop i5 hashes MD5
//!   faster than 1 Gbps (paper: "the speed of checksum is faster than the
//!   speed of transfer").
//! * HPCLab-40G: NVMe SSDs, 40 Gbps link, E5-2623 MD5 ~3 Gbps (paper: "the
//!   speed of transfer is faster than the speed of checksum").

use crate::hashes::{HashAlgorithm, HashTier};
use crate::net::TcpParams;
use crate::storage::IoBackend;

/// Convert Gbps to bytes/sec.
pub const fn gbps(g: f64) -> f64 {
    g * 1e9 / 8.0
}

/// One kibibyte.
pub const KB: u64 = 1 << 10;
/// One mebibyte.
pub const MB: u64 = 1 << 20;
/// One gibibyte.
pub const GB: u64 = 1 << 30;

/// Per-host I/O and compute rates.
#[derive(Debug, Clone, Copy)]
pub struct HostSpec {
    /// Sequential disk read rate (bytes/s).
    pub disk_read: f64,
    /// Sequential disk write rate (bytes/s).
    pub disk_write: f64,
    /// Page-cache (memory bus) read rate for cached checksum I/O.
    pub mem_read: f64,
    /// MD5 hash rate of one checksum thread (bytes/s); other algorithms
    /// scale by [`HashAlgorithm::relative_cost`].
    pub hash_md5: f64,
    /// Free memory available to the page cache (bytes).
    pub free_mem: u64,
}

impl HostSpec {
    /// This host's hash throughput in bytes/sec for `alg`.
    pub fn hash_rate(&self, alg: HashAlgorithm) -> f64 {
        self.hash_md5 / alg.relative_cost()
    }
}

/// A source-destination pair plus network path (one row of Table I/II).
#[derive(Debug, Clone, Copy)]
pub struct Testbed {
    /// Testbed name as used in the paper and on the CLI.
    pub name: &'static str,
    /// Source-host capabilities.
    pub src: HostSpec,
    /// Destination-host capabilities.
    pub dst: HostSpec,
    /// Link bandwidth (bytes/s).
    pub bandwidth: f64,
    /// Round-trip time (seconds).
    pub rtt: f64,
}

impl Testbed {
    /// The TCP envelope for this testbed's link.
    pub fn tcp_params(&self) -> TcpParams {
        TcpParams::new(self.bandwidth, self.rtt)
    }

    /// ESNet @ Berkeley (Table I): 24-HDD RAID0 source, 12-SSD RAID0
    /// destination, 100 Gbps NICs but 5-6 Gbps achieved disk I/O; LAN path
    /// through a top-of-rack switch (0.2 ms RTT).
    pub fn esnet_lan() -> Testbed {
        Testbed {
            name: "ESNet-LAN",
            src: HostSpec {
                disk_read: gbps(5.75),
                disk_write: gbps(5.0),
                mem_read: gbps(64.0),
                hash_md5: gbps(2.93),
                free_mem: 12 * GB,
            },
            dst: HostSpec {
                disk_read: gbps(8.0),
                disk_write: gbps(6.0),
                mem_read: gbps(64.0),
                hash_md5: gbps(2.93),
                free_mem: 12 * GB,
            },
            // Evaluation text: "the network bandwidth is 40 Gbps" on the
            // LAN path (100 G NICs, 40 G achievable through the ToR).
            bandwidth: gbps(40.0),
            rtt: 0.2e-3,
        }
    }

    /// ESNet WAN loop Berkeley -> Starlight@Chicago -> Berkeley, 89 ms RTT.
    pub fn esnet_wan() -> Testbed {
        Testbed { name: "ESNet-WAN", rtt: 89e-3, ..Self::esnet_lan() }
    }

    /// HPCLab WS1-WS2 (Table II): desktop workstations, direct-attached
    /// HDD, 1 Gbps LAN. Checksum (i5-7600 MD5 ~3.4 Gbps) outruns both the
    /// network and the HDD.
    pub fn hpclab_1g() -> Testbed {
        let ws = HostSpec {
            disk_read: gbps(1.45),
            disk_write: gbps(1.3),
            mem_read: gbps(40.0),
            hash_md5: gbps(3.4),
            free_mem: 14 * GB, // 16 GB RAM minus OS/app working set
        };
        Testbed { name: "HPCLab-1G", src: ws, dst: ws, bandwidth: gbps(1.0), rtt: 0.2e-3 }
    }

    /// HPCLab DTN1-DTN2 (Table II): NVMe SSDs, 40 Gbps link, 30 ms emulated
    /// RTT, 64 GB RAM. Network outruns MD5 (~3 Gbps on the E5-2623). The
    /// effective disk-to-disk path is calibrated to ~6 Gbps from the
    /// paper's own Fig 5a numbers (file-level pipelining at ~60-70% on a
    /// single 10G file implies t_transfer ≈ 0.5-0.7 x t_checksum): a
    /// 2017-era single direct-attached NVMe sustains ~750 MB/s synced
    /// sequential writes through the filesystem.
    pub fn hpclab_40g() -> Testbed {
        let dtn = HostSpec {
            disk_read: gbps(12.0),
            disk_write: gbps(6.0),
            mem_read: gbps(80.0),
            hash_md5: gbps(3.0),
            free_mem: 56 * GB,
        };
        Testbed { name: "HPCLab-40G", src: dtn, dst: dtn, bandwidth: gbps(40.0), rtt: 30e-3 }
    }

    /// Look a testbed up by CLI name.
    pub fn by_name(name: &str) -> Option<Testbed> {
        match name.to_ascii_lowercase().as_str() {
            "esnet-lan" | "esnet_lan" => Some(Self::esnet_lan()),
            "esnet-wan" | "esnet_wan" => Some(Self::esnet_wan()),
            "hpclab-1g" | "hpclab_1g" => Some(Self::hpclab_1g()),
            "hpclab-40g" | "hpclab_40g" => Some(Self::hpclab_40g()),
            _ => None,
        }
    }

    /// All four paper testbeds.
    pub fn all() -> [Testbed; 4] {
        [Self::esnet_lan(), Self::esnet_wan(), Self::hpclab_1g(), Self::hpclab_40g()]
    }
}

/// Tunable algorithm parameters (paper §IV defaults).
#[derive(Debug, Clone, Copy)]
pub struct AlgoParams {
    /// Block size for block-level pipelining (paper: 256 MB).
    pub block_size: u64,
    /// FIVER chunk size for chunk-level integrity verification
    /// (paper Table III: set equal to the block size).
    pub chunk_size: u64,
    /// Merkle leaf span for FIVER-Merkle: repair granularity; a mismatch
    /// costs O(log(size/leaf_size)) digest round trips to localize.
    pub leaf_size: u64,
    /// Shared-queue capacity in bytes (Algorithm 1 & 2 "fixed size,
    /// synchronized queue"): bounds transfer/checksum decoupling.
    pub queue_capacity: u64,
    /// Per-file control exchange cost in RTTs (metadata + final digest
    /// compare).
    pub control_rtts: f64,
    /// Hash algorithm in use.
    pub hash: HashAlgorithm,
    /// Read-path slowdown for checksums fed through the filesystem while a
    /// transfer is in flight (syscall + user/kernel context switching the
    /// paper cites for block-/file-level pipelining); FIVER's queue handoff
    /// avoids it. Dimensionless multiplier on per-byte hash cost.
    pub fs_read_factor: f64,
    /// Parallel engine: files smaller than this aggregate into batched
    /// work items ([`crate::workload::plan_batches`]) so lots-of-small-
    /// files datasets (1000×10M) schedule in amortized groups; 0 disables.
    pub batch_threshold: u64,
    /// Parallel engine: target payload per batched work item.
    pub batch_bytes: u64,
    /// Data-plane buffer pool size in buffers of `io_buf_size` bytes,
    /// shared by every session at an endpoint (the real engine's
    /// [`crate::coordinator::bufpool::BufferPool`]). 0 = unbounded: the
    /// pool never throttles. A finite pool caps aggregate in-flight bytes;
    /// sweeps shrink it to expose pool-starvation regimes
    /// ([`crate::sim::testbed::SimEnv::new_parallel`] models the cap via
    /// Little's law).
    pub pool_buffers: u64,
    /// I/O buffer granularity of the data plane (one pooled buffer per
    /// read; the real engine's `SessionConfig::buf_size`).
    pub io_buf_size: u64,
    /// Storage I/O engine modeled by the sim (the real engine's
    /// `--io-backend`): decides per-byte read/write weights and whether
    /// the page cache participates at all — see [`IoCost`].
    pub io_backend: IoBackend,
    /// Delta-sync model (the real engine's `--delta`): fraction of the
    /// dataset's bytes that are *dirty* — changed since the receiver's
    /// copy — and must cross the wire. 1.0 (the default) is a full copy:
    /// every byte ships and no delta machinery runs. Below 1.0 the sim
    /// charges the sender a full read+scan pass, ships only the dirty
    /// fraction, and charges the receiver local copy + re-hash of the
    /// reconstructed file (see `sim::algorithms::run_delta`).
    pub delta_fraction: f64,
    /// Hash tiering (the real engine's `--hash-tier`): which digest
    /// family the per-byte leaf hashing uses. `Cryptographic` (the
    /// default) charges every byte at `hash`'s rate — the pre-tiering
    /// model, bit-identical outputs. `Fast` charges everything at
    /// XXH3-128's rate. `Tiered` charges leaf bytes at XXH3-128's rate
    /// plus the cryptographic fold over interior digest bytes — see
    /// [`AlgoParams::leaf_hash_rate`].
    pub hash_tier: HashTier,
}

impl AlgoParams {
    /// Effective per-byte hash throughput of `host` under this run's
    /// tier. For `Tiered`, leaf bytes hash at XXH3's rate and the
    /// cryptographic algorithm only folds interior nodes: a binary fold
    /// over `leaf_size`-spaced leaves touches ~`2 * dlen` digest bytes
    /// per leaf (the geometric sum over levels), so per data byte the
    /// crypto share is `2 * dlen / leaf_size` — the Eq. 1 cost table's
    /// tiered row.
    pub fn leaf_hash_rate(&self, host: &HostSpec) -> f64 {
        let fast = host.hash_rate(HashAlgorithm::Xxh3128);
        match self.hash_tier {
            HashTier::Cryptographic => host.hash_rate(self.hash),
            HashTier::Fast => fast,
            HashTier::Tiered => {
                let fold_frac =
                    2.0 * self.leaf_digest_len() as f64 / self.leaf_size.max(1) as f64;
                1.0 / (1.0 / fast + fold_frac / host.hash_rate(self.hash))
            }
        }
    }

    /// Per-leaf digest width under this run's tier (bytes): XXH3-128's
    /// 16 for fast-tier leaves, else the cryptographic algorithm's.
    pub fn leaf_digest_len(&self) -> usize {
        match self.hash_tier {
            HashTier::Cryptographic => self.hash.hasher().digest_len(),
            HashTier::Fast | HashTier::Tiered => 16,
        }
    }
}

/// The sim's per-backend storage cost model (dimensionless weights on the
/// fluid-engine resources; `buffered` is the identity so default sims
/// reproduce the pre-backend numbers bit-for-bit).
///
/// Calibration rationale, qualitative but grounded:
///
/// * **buffered** — reads of cached bytes cross the memory bus twice
///   (page-cache copy into the user buffer, then the hash/socket pass);
///   the weights below are normalized to that baseline, so 1.0 / 1.0.
/// * **mmap** — no kernel→user copy: the hash and socket consume the
///   page-cache pages in place, so a cached read costs roughly half the
///   bus traffic (`cached_read_weight 0.55`, the extra 0.05 for fault-in
///   bookkeeping). Writes fault pages in before storing into them, a
///   small surcharge on the destination disk path
///   (`write_weight_mult 1.05`).
/// * **direct** — bypasses the page cache entirely
///   (`bypass_page_cache`): every read is a disk read, writes don't warm
///   the destination cache (so read-back verification — FIVER-Hybrid's
///   receiver-side checksum — always pays disk), but the write path
///   skips the double buffering (`write_weight_mult 0.92`).
/// * **uring** — same page-cache behavior as buffered (the ring reads
///   and writes through the cache), but submission-queue batching
///   amortizes syscall + mode-switch overhead across a readahead batch
///   (`syscall_weight 0.8` ≈ one `io_uring_enter` per 4-deep batch
///   instead of one `pread` per chunk) and registered buffers shave the
///   per-op pinning on the write side (`write_weight_mult 0.97`).
/// * **auto** — models as buffered: the sim has no per-file size mix
///   inside one run, and below the threshold auto *is* buffered.
///
/// `syscall_weight` multiplies the per-byte *software* cost of cached
/// reads (the syscall/mode-switch share of the memory-bus path); 1.0 for
/// every pre-uring backend keeps their pinned sim outputs bit-identical.
#[derive(Debug, Clone, Copy)]
pub struct IoCost {
    /// Multiplier on the destination-disk weight per written byte.
    pub write_weight_mult: f64,
    /// Memory-bus weight of reading one *cached* byte.
    pub cached_read_weight: f64,
    /// Direct I/O: reads never hit the cache, writes never warm it.
    pub bypass_page_cache: bool,
    /// Syscall-batching multiplier on the cached-read software path
    /// (1.0 = one syscall per chunk; <1 = submissions amortized).
    pub syscall_weight: f64,
}

impl IoCost {
    /// The cost model for `backend`.
    pub fn of(backend: IoBackend) -> IoCost {
        match backend {
            IoBackend::Buffered | IoBackend::Auto => IoCost {
                write_weight_mult: 1.0,
                cached_read_weight: 1.0,
                bypass_page_cache: false,
                syscall_weight: 1.0,
            },
            IoBackend::Mmap => IoCost {
                write_weight_mult: 1.05,
                cached_read_weight: 0.55,
                bypass_page_cache: false,
                syscall_weight: 1.0,
            },
            IoBackend::Direct => IoCost {
                write_weight_mult: 0.92,
                cached_read_weight: 1.0,
                bypass_page_cache: true,
                syscall_weight: 1.0,
            },
            IoBackend::Uring => IoCost {
                write_weight_mult: 0.97,
                cached_read_weight: 1.0,
                bypass_page_cache: false,
                syscall_weight: 0.8,
            },
        }
    }
}

impl Default for AlgoParams {
    fn default() -> Self {
        AlgoParams {
            block_size: 256 * MB,
            chunk_size: 256 * MB,
            leaf_size: 64 * KB,
            queue_capacity: 64 * MB,
            control_rtts: 1.0,
            hash: HashAlgorithm::Md5,
            fs_read_factor: 1.12,
            batch_threshold: 16 * MB,
            batch_bytes: 64 * MB,
            pool_buffers: 0,
            io_buf_size: 256 * KB,
            io_backend: IoBackend::Buffered,
            delta_fraction: 1.0,
            hash_tier: HashTier::Cryptographic,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_paper_rate_relationships() {
        // HPCLab-1G: checksum faster than transfer.
        let t = Testbed::hpclab_1g();
        assert!(t.src.hash_md5 > t.bandwidth);
        // HPCLab-40G and ESNet: transfer faster than checksum.
        for t in [Testbed::hpclab_40g(), Testbed::esnet_lan()] {
            let path = t.src.disk_read.min(t.bandwidth).min(t.dst.disk_write);
            assert!(path > t.src.hash_md5, "{}: path {} <= hash {}", t.name, path, t.src.hash_md5);
        }
    }

    #[test]
    fn esnet_calibration_close_to_paper() {
        // 100 GB: ~140 s transfer (disk-limited), ~273 s checksum.
        let t = Testbed::esnet_lan();
        let size = 100.0 * GB as f64;
        let transfer = size / t.src.disk_read.min(t.bandwidth).min(t.dst.disk_write);
        let checksum = size / t.src.hash_md5;
        assert!((transfer - 140.0).abs() < 25.0, "transfer {transfer}");
        assert!((checksum - 273.0).abs() < 30.0, "checksum {checksum}");
    }

    #[test]
    fn tiered_leaf_rate_tracks_fast_tier() {
        let t = Testbed::esnet_lan();
        let crypto = AlgoParams { hash: HashAlgorithm::Sha1, ..Default::default() };
        let tiered = AlgoParams { hash_tier: HashTier::Tiered, ..crypto };
        let fast = AlgoParams { hash_tier: HashTier::Fast, ..crypto };
        // Tiered leaves must be at least 2x the cryptographic rate (the
        // acceptance bar) and within a few percent of pure-fast: the
        // crypto fold only touches ~2*dlen/leaf_size of the bytes.
        assert!(tiered.leaf_hash_rate(&t.src) > 2.0 * crypto.leaf_hash_rate(&t.src));
        assert!(tiered.leaf_hash_rate(&t.src) > 0.95 * fast.leaf_hash_rate(&t.src));
        assert!(tiered.leaf_hash_rate(&t.src) < fast.leaf_hash_rate(&t.src));
        // Widths follow the tier's leaf family.
        assert_eq!(crypto.leaf_digest_len(), 20);
        assert_eq!(tiered.leaf_digest_len(), 16);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Testbed::by_name("ESNet-WAN").unwrap().name, "ESNet-WAN");
        assert_eq!(Testbed::by_name("hpclab_40g").unwrap().name, "HPCLab-40G");
        assert!(Testbed::by_name("nope").is_none());
    }

    #[test]
    fn wan_differs_from_lan_only_in_rtt() {
        let lan = Testbed::esnet_lan();
        let wan = Testbed::esnet_wan();
        assert_eq!(lan.bandwidth, wan.bandwidth);
        assert!(wan.rtt > 100.0 * lan.rtt);
    }

    #[test]
    fn hash_rates_scale_by_cost() {
        let h = Testbed::esnet_lan().src;
        assert!(h.hash_rate(HashAlgorithm::Sha256) < h.hash_rate(HashAlgorithm::Sha1));
        assert!(h.hash_rate(HashAlgorithm::Sha1) < h.hash_rate(HashAlgorithm::Md5));
    }

    #[test]
    fn default_params_match_paper() {
        let p = AlgoParams::default();
        assert_eq!(p.block_size, 256 * MB);
        assert_eq!(p.chunk_size, p.block_size);
        assert_eq!(p.leaf_size, 64 * KB);
        assert_eq!(p.io_backend, IoBackend::Buffered);
    }

    #[test]
    fn buffered_io_cost_is_identity() {
        // The default backend must reproduce pre-backend sim numbers
        // bit-for-bit: every weight neutral, page cache participating.
        let c = IoCost::of(IoBackend::Buffered);
        assert_eq!(c.write_weight_mult, 1.0);
        assert_eq!(c.cached_read_weight, 1.0);
        assert!(!c.bypass_page_cache);
        assert_eq!(c.syscall_weight, 1.0);
        // Pre-uring backends keep a neutral syscall term so their pinned
        // sim outputs stay bit-identical; uring is the one that batches.
        assert_eq!(IoCost::of(IoBackend::Mmap).syscall_weight, 1.0);
        assert_eq!(IoCost::of(IoBackend::Direct).syscall_weight, 1.0);
        assert!(IoCost::of(IoBackend::Uring).syscall_weight < 1.0);
        assert_eq!(IoCost::of(IoBackend::Auto).cached_read_weight, 1.0);
        // mmap reads cached bytes cheaper than buffered; direct bypasses.
        assert!(IoCost::of(IoBackend::Mmap).cached_read_weight < 1.0);
        assert!(IoCost::of(IoBackend::Direct).bypass_page_cache);
    }
}
