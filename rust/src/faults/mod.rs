//! Fault injection: silent data corruption on the wire path, plus
//! mid-transfer process kills.
//!
//! The paper's Table III experiment "injected faults by flipping a random
//! bit of randomly-chosen files during the transfer operation". This module
//! provides the fault plan (which files/offsets corrupt, deterministic by
//! seed) used by both the simulator and the real-mode coordinator (where
//! a [`FaultInjector`] literally flips bits in the socket-bound buffers).
//!
//! A plan can also carry a [`CrashPoint`]: after a chosen number of
//! streamed payload bytes, every sender session aborts at its next frame
//! boundary as if the process were killed — the deterministic trigger the
//! crash-recovery harness (`rust/tests/crash_recovery.rs`) and the sim's
//! restart modeling drive. The budget is shared across sessions through
//! an `Arc`, so one plan kills the whole engine, not one thread.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

use crate::util::rng::SplitMix64;
use crate::workload::Dataset;

/// Error marker for an injected crash (the engine was "killed"; the
/// transfer is expected to resume from its checkpoint journal).
#[derive(Debug, Clone, Copy)]
pub struct CrashError;

impl std::fmt::Display for CrashError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected crash: engine killed mid-transfer")
    }
}

impl std::error::Error for CrashError {}

/// A planned mid-transfer kill: the engine dies at the first data-frame
/// boundary once `after_bytes` payload bytes have been streamed (summed
/// across every concurrent session — clones share the budget).
#[derive(Debug, Clone)]
pub struct CrashPoint {
    after_bytes: u64,
    remaining: Arc<AtomicI64>,
}

impl CrashPoint {
    /// Crash once `n` payload bytes have been sent.
    pub fn after_bytes(n: u64) -> CrashPoint {
        let budget = n.min(i64::MAX as u64) as i64;
        CrashPoint { after_bytes: n, remaining: Arc::new(AtomicI64::new(budget)) }
    }

    /// The configured kill threshold (the sim's restart models read it).
    pub fn threshold(&self) -> u64 {
        self.after_bytes
    }

    /// Has the byte budget been spent? Senders check this before putting
    /// the next frame on the wire and abort with [`CrashError`] once true.
    pub fn tripped(&self) -> bool {
        self.remaining.load(Ordering::SeqCst) <= 0
    }

    /// Account `n` streamed payload bytes against the budget.
    pub fn consume(&self, n: u64) {
        self.remaining.fetch_sub(n.min(i64::MAX as u64) as i64, Ordering::SeqCst);
    }
}

/// One planned corruption: flip `bit` of byte `offset` in file `file_idx`
/// on its `occurrence`-th transfer attempt (0 = first attempt; re-transfers
/// of a repaired file are clean unless a later occurrence is planned).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    /// Index of the file the fault corrupts.
    pub file_idx: usize,
    /// Byte offset within the file.
    pub offset: u64,
    /// Which bit to flip at `offset`.
    pub bit: u8,
    /// Which read of that byte gets corrupted (so repairs can succeed).
    pub occurrence: u32,
}

/// A deterministic fault plan over a dataset.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// The injected faults.
    pub faults: Vec<Fault>,
    /// Optional mid-transfer kill (see [`CrashPoint`]).
    pub crash: Option<CrashPoint>,
}

impl FaultPlan {
    /// No faults.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// This plan, plus a process kill after `bytes` streamed bytes.
    pub fn with_crash_after_bytes(mut self, bytes: u64) -> FaultPlan {
        self.crash = Some(CrashPoint::after_bytes(bytes));
        self
    }

    /// `count` faults on distinct random (file, offset) positions, all on
    /// first-attempt transfers (the paper's Table III setup: 0 / 8 / 24).
    /// Byte-position-weighted by file size, as random wire corruption is.
    pub fn random(dataset: &Dataset, count: usize, seed: u64) -> FaultPlan {
        let mut rng = SplitMix64::new(seed);
        let total: u64 = dataset.total_bytes();
        assert!(total > 0 || count == 0, "cannot corrupt an empty dataset");
        let mut faults = Vec::with_capacity(count);
        for _ in 0..count {
            let mut pos = rng.below(total);
            let mut file_idx = 0;
            for (i, f) in dataset.files.iter().enumerate() {
                if pos < f.size {
                    file_idx = i;
                    break;
                }
                pos -= f.size;
            }
            faults.push(Fault {
                file_idx,
                offset: pos,
                bit: (rng.below(8)) as u8,
                occurrence: 0,
            });
        }
        faults.sort_by_key(|f| (f.file_idx, f.offset));
        FaultPlan { faults, crash: None }
    }

    /// Faults hitting a specific file (for targeted tests).
    pub fn at(file_idx: usize, offset: u64, bit: u8) -> FaultPlan {
        FaultPlan { faults: vec![Fault { file_idx, offset, bit, occurrence: 0 }], crash: None }
    }

    /// Faults planned for a given file + attempt.
    pub fn for_attempt(&self, file_idx: usize, occurrence: u32) -> Vec<Fault> {
        self.faults
            .iter()
            .filter(|f| f.file_idx == file_idx && f.occurrence == occurrence)
            .copied()
            .collect()
    }

    /// Number of faults in the plan.
    pub fn count(&self) -> usize {
        self.faults.len()
    }

    /// Flip the bits planned for `(file_idx, occurrence)` that fall inside
    /// the window `[window_start, window_start + buf.len())` of the file,
    /// directly in `buf`. Returns the number of flips applied. This is the
    /// repair-path twin of [`FaultInjector::corrupt`]: re-sent bytes (Fix
    /// frames) count as occurrence `n` of the range they cover, so a fault
    /// plan can corrupt a *re*-transfer attempt too.
    pub fn corrupt_in_place(
        &self,
        file_idx: usize,
        occurrence: u32,
        window_start: u64,
        buf: &mut [u8],
    ) -> usize {
        let hi = window_start + buf.len() as u64;
        let mut applied = 0;
        for f in &self.faults {
            if f.file_idx == file_idx
                && f.occurrence == occurrence
                && f.offset >= window_start
                && f.offset < hi
            {
                buf[(f.offset - window_start) as usize] ^= 1 << f.bit;
                applied += 1;
            }
        }
        applied
    }

    /// Highest planned occurrence for a file (0 when only first-attempt
    /// faults exist). Repair loops converge once attempts exceed this.
    pub fn max_occurrence(&self, file_idx: usize) -> u32 {
        self.faults
            .iter()
            .filter(|f| f.file_idx == file_idx)
            .map(|f| f.occurrence)
            .max()
            .unwrap_or(0)
    }
}

/// Applies a fault plan to in-flight buffers (real mode). Tracks the byte
/// window of the current file as it streams and flips planned bits.
#[derive(Debug)]
pub struct FaultInjector {
    faults: Vec<Fault>,
    /// Bytes of the current (file, attempt) streamed so far.
    window_start: u64,
    current_file: usize,
    current_attempt: u32,
}

impl FaultInjector {
    /// An injector executing `plan`.
    pub fn new(plan: &FaultPlan) -> FaultInjector {
        FaultInjector {
            faults: plan.faults.clone(),
            window_start: 0,
            current_file: usize::MAX,
            current_attempt: 0,
        }
    }

    /// Begin streaming `file_idx`, attempt `occurrence`.
    pub fn start_file(&mut self, file_idx: usize, occurrence: u32) {
        self.start_file_at(file_idx, occurrence, 0);
    }

    /// Begin streaming `file_idx` from byte `offset` (a journal-resumed
    /// tail): planned fault offsets keep their whole-file coordinates.
    pub fn start_file_at(&mut self, file_idx: usize, occurrence: u32, offset: u64) {
        self.current_file = file_idx;
        self.current_attempt = occurrence;
        self.window_start = offset;
    }

    /// Would [`FaultInjector::corrupt`] flip anything in the next `len`
    /// bytes? The zero-copy stream path checks this before deciding
    /// whether it needs a mutable copy of the outbound window (the clean
    /// path sends the shared buffer untouched and calls
    /// [`FaultInjector::advance`] instead).
    pub fn will_corrupt(&self, len: usize) -> bool {
        let lo = self.window_start;
        let hi = lo + len as u64;
        self.faults.iter().any(|f| {
            f.file_idx == self.current_file
                && f.occurrence == self.current_attempt
                && f.offset >= lo
                && f.offset < hi
        })
    }

    /// Advance the stream window past `len` clean (untouched) bytes —
    /// the zero-copy twin of [`FaultInjector::corrupt`].
    pub fn advance(&mut self, len: usize) {
        self.window_start += len as u64;
    }

    /// Corrupt `buf` (about to be sent at the current stream position).
    /// Returns the applied flips as (index-in-buf, bit) — XOR is
    /// self-inverse, so callers can restore the clean bytes for local
    /// hashing after putting the corrupted copy on the wire.
    pub fn corrupt(&mut self, buf: &mut [u8]) -> Vec<(usize, u8)> {
        let lo = self.window_start;
        let hi = lo + buf.len() as u64;
        let mut flipped = Vec::new();
        for f in &self.faults {
            if f.file_idx == self.current_file
                && f.occurrence == self.current_attempt
                && f.offset >= lo
                && f.offset < hi
            {
                buf[(f.offset - lo) as usize] ^= 1 << f.bit;
                flipped.push(((f.offset - lo) as usize, f.bit));
            }
        }
        self.window_start = hi;
        flipped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MB;

    fn dataset() -> Dataset {
        Dataset::uniform("t", 10 * MB, 4)
    }

    #[test]
    fn plan_is_deterministic() {
        let d = dataset();
        let a = FaultPlan::random(&d, 8, 42);
        let b = FaultPlan::random(&d, 8, 42);
        assert_eq!(a.faults, b.faults);
        let c = FaultPlan::random(&d, 8, 43);
        assert_ne!(a.faults, c.faults);
    }

    #[test]
    fn plan_count_and_bounds() {
        let d = dataset();
        let p = FaultPlan::random(&d, 24, 1);
        assert_eq!(p.count(), 24);
        for f in &p.faults {
            assert!(f.file_idx < d.len());
            assert!(f.offset < d.files[f.file_idx].size);
            assert!(f.bit < 8);
        }
    }

    #[test]
    fn for_attempt_filters() {
        let p = FaultPlan::at(2, 100, 3);
        assert_eq!(p.for_attempt(2, 0).len(), 1);
        assert_eq!(p.for_attempt(2, 1).len(), 0);
        assert_eq!(p.for_attempt(1, 0).len(), 0);
    }

    #[test]
    fn injector_flips_exactly_planned_bit() {
        let p = FaultPlan::at(0, 5, 7);
        let mut inj = FaultInjector::new(&p);
        inj.start_file(0, 0);
        let mut buf = vec![0u8; 10];
        let flipped = inj.corrupt(&mut buf);
        assert_eq!(flipped, vec![(5, 7)]);
        assert_eq!(buf[5], 0x80);
        assert!(buf.iter().enumerate().all(|(i, &b)| i == 5 || b == 0));
    }

    #[test]
    fn injector_windows_across_buffers() {
        let p = FaultPlan::at(0, 15, 0);
        let mut inj = FaultInjector::new(&p);
        inj.start_file(0, 0);
        let mut b1 = vec![0u8; 10];
        let mut b2 = vec![0u8; 10];
        assert!(inj.corrupt(&mut b1).is_empty());
        assert_eq!(inj.corrupt(&mut b2), vec![(5, 0)]);
        assert_eq!(b2[5], 0x01);
    }

    #[test]
    fn retransfer_attempt_is_clean() {
        let p = FaultPlan::at(0, 5, 0);
        let mut inj = FaultInjector::new(&p);
        inj.start_file(0, 1); // second attempt
        let mut buf = vec![0u8; 10];
        assert!(inj.corrupt(&mut buf).is_empty());
    }

    #[test]
    fn corrupt_in_place_honors_occurrence_and_window() {
        let plan = FaultPlan {
            faults: vec![
                Fault { file_idx: 1, offset: 105, bit: 0, occurrence: 1 },
                Fault { file_idx: 1, offset: 105, bit: 1, occurrence: 2 },
                Fault { file_idx: 0, offset: 105, bit: 2, occurrence: 1 },
            ],
            crash: None,
        };
        let mut buf = vec![0u8; 10];
        // Wrong occurrence: untouched.
        assert_eq!(plan.corrupt_in_place(1, 0, 100, &mut buf), 0);
        assert!(buf.iter().all(|&b| b == 0));
        // Occurrence 1 in-window: exactly the planned bit flips.
        assert_eq!(plan.corrupt_in_place(1, 1, 100, &mut buf), 1);
        assert_eq!(buf[5], 0x01);
        // Out of window: untouched.
        let mut buf2 = vec![0u8; 10];
        assert_eq!(plan.corrupt_in_place(1, 1, 200, &mut buf2), 0);
        assert_eq!(plan.max_occurrence(1), 2);
        assert_eq!(plan.max_occurrence(9), 0);
    }

    #[test]
    fn crash_point_trips_once_budget_spent_and_is_shared() {
        let plan = FaultPlan::none().with_crash_after_bytes(100);
        let c = plan.crash.as_ref().unwrap();
        assert_eq!(c.threshold(), 100);
        assert!(!c.tripped());
        c.consume(60);
        assert!(!c.tripped(), "under budget");
        // Clones (other sessions) share the same budget.
        let c2 = c.clone();
        c2.consume(40);
        assert!(c.tripped(), "budget spent across clones");
        assert!(c2.tripped());
        // Zero-budget plans are dead on arrival (crash before frame 1).
        let now = CrashPoint::after_bytes(0);
        assert!(now.tripped());
    }

    #[test]
    fn injector_resumed_tail_keeps_file_coordinates() {
        // A fault at absolute offset 15 must strike a tail stream that
        // resumes at byte 10, at buffer position 5.
        let p = FaultPlan::at(0, 15, 3);
        let mut inj = FaultInjector::new(&p);
        inj.start_file_at(0, 0, 10);
        let mut buf = vec![0u8; 10];
        assert_eq!(inj.corrupt(&mut buf), vec![(5, 3)]);
        assert_eq!(buf[5], 0x08);
        // A fault below the resume offset can never strike the tail.
        let p = FaultPlan::at(0, 5, 0);
        let mut inj = FaultInjector::new(&p);
        inj.start_file_at(0, 0, 10);
        let mut buf = vec![0u8; 10];
        assert!(inj.corrupt(&mut buf).is_empty());
    }

    #[test]
    fn zero_faults_touch_nothing() {
        let mut inj = FaultInjector::new(&FaultPlan::none());
        inj.start_file(0, 0);
        let mut buf = vec![0xFFu8; 64];
        assert!(inj.corrupt(&mut buf).is_empty());
        assert!(buf.iter().all(|&b| b == 0xFF));
    }
}
