//! Property tests over coordinator invariants (in-tree generator — no
//! proptest crate offline; see rust/src/util/rng.rs). Each property runs
//! against dozens of seeded random configurations; failures print the seed
//! for replay.

use std::sync::Arc;

use fiver::coordinator::queue::ByteQueue;
use fiver::coordinator::session::run_local_transfer;
use fiver::coordinator::{native_factory, protocol, RealAlgorithm, SessionConfig};
use fiver::faults::{Fault, FaultPlan};
use fiver::hashes::{HashAlgorithm, HashTier};
use fiver::storage::MemStorage;
use fiver::util::rng::SplitMix64;

/// PROPERTY: any dataset + any fault set + any algorithm => every file is
/// delivered bit-identical and every injected fault is detected.
#[test]
fn prop_recovery_completeness() {
    for seed in 0..12u64 {
        let mut rng = SplitMix64::new(seed * 7919 + 13);
        let n_files = rng.range(1, 5) as usize;
        let mut sizes = Vec::new();
        for _ in 0..n_files {
            // Mix of tiny and multi-chunk files.
            let size = match rng.below(3) {
                0 => rng.range(0, 1000),
                1 => rng.range(1000, 300_000),
                _ => rng.range(300_000, 1_500_000),
            };
            sizes.push(size as usize);
        }
        // Random faults over non-empty files.
        let mut faults = FaultPlan::none();
        let n_faults = rng.below(5) as usize;
        for _ in 0..n_faults {
            let fi = rng.below(n_files as u64) as usize;
            if sizes[fi] == 0 {
                continue;
            }
            faults.faults.push(Fault {
                file_idx: fi,
                offset: rng.below(sizes[fi] as u64),
                bit: rng.below(8) as u8,
                occurrence: 0,
            });
        }
        let algs: Vec<RealAlgorithm> = RealAlgorithm::ALL
            .into_iter()
            .filter(|a| *a != RealAlgorithm::TransferOnly)
            .collect();
        let alg = algs[rng.below(algs.len() as u64) as usize];

        // Build source.
        let src = MemStorage::new();
        let mut names = Vec::new();
        let mut contents = Vec::new();
        for (i, &size) in sizes.iter().enumerate() {
            let mut data = vec![0u8; size];
            rng.fork().fill_bytes(&mut data);
            let name = format!("p{i}");
            src.put(&name, data.clone());
            names.push(name);
            contents.push(data);
        }
        let dst = MemStorage::new();
        let mut cfg = SessionConfig::new(alg, native_factory(HashAlgorithm::Fvr256));
        cfg.buf_size = rng.range(1000, 100_000) as usize;
        cfg.block_size = rng.range(50_000, 400_000);
        cfg.queue_capacity = rng.range(10_000, 500_000) as usize;
        cfg.hybrid_threshold = rng.range(1000, 1_000_000);

        let (report, _) = run_local_transfer(
            &names,
            Arc::new(src),
            Arc::new(dst.clone()),
            &cfg,
            &faults,
        )
        .unwrap_or_else(|e| panic!("seed {seed} ({}) failed: {e:#}", alg.name()));

        let effective_faults =
            faults.faults.iter().filter(|f| sizes[f.file_idx] > 0).count() as u64;
        assert!(
            report.failures_detected >= effective_faults.min(1) * (effective_faults > 0) as u64,
            "seed {seed}: {} faults, {} detected",
            effective_faults,
            report.failures_detected
        );
        for (name, expect) in names.iter().zip(&contents) {
            let got = dst.get(name).unwrap_or_else(|| panic!("seed {seed}: missing {name}"));
            assert_eq!(&got, expect, "seed {seed} {}: delivered bytes differ", alg.name());
        }
    }
}

/// PROPERTY: fault plans that also corrupt *re*-transfer attempts
/// (occurrence > 0) still converge — the repair loop never ping-pongs —
/// and the repaired destination bytes always equal the source bytes, for
/// every verifying algorithm including FIVER-Merkle.
#[test]
fn prop_retransfer_faults_converge() {
    for seed in 0..10u64 {
        let mut rng = SplitMix64::new(seed * 104_729 + 7);
        let n_files = rng.range(1, 4) as usize;
        let mut sizes = Vec::new();
        for _ in 0..n_files {
            sizes.push(rng.range(10_000, 900_000) as usize);
        }
        // Random faults on attempts 0..=2: occurrence-n faults strike the
        // n-th repair round's re-sent bytes (if the round covers them).
        let mut faults = FaultPlan::none();
        for _ in 0..rng.range(1, 6) {
            let fi = rng.below(n_files as u64) as usize;
            faults.faults.push(Fault {
                file_idx: fi,
                offset: rng.below(sizes[fi] as u64),
                bit: rng.below(8) as u8,
                occurrence: rng.below(3) as u32,
            });
        }
        let algs: Vec<RealAlgorithm> = RealAlgorithm::ALL
            .into_iter()
            .filter(|a| *a != RealAlgorithm::TransferOnly)
            .collect();
        let alg = algs[rng.below(algs.len() as u64) as usize];

        let src = MemStorage::new();
        let mut names = Vec::new();
        let mut contents = Vec::new();
        for (i, &size) in sizes.iter().enumerate() {
            let mut data = vec![0u8; size];
            rng.fork().fill_bytes(&mut data);
            let name = format!("r{i}");
            src.put(&name, data.clone());
            names.push(name);
            contents.push(data);
        }
        let dst = MemStorage::new();
        let mut cfg = SessionConfig::new(alg, native_factory(HashAlgorithm::Fvr256));
        cfg.buf_size = 32_768;
        cfg.block_size = 131_072;
        cfg.queue_capacity = 262_144;
        cfg.leaf_size = 16_384;
        cfg.hybrid_threshold = 400_000;
        let (report, _) = run_local_transfer(
            &names,
            Arc::new(src),
            Arc::new(dst.clone()),
            &cfg,
            &faults,
        )
        .unwrap_or_else(|e| panic!("seed {seed} ({}) failed: {e:#}", alg.name()));

        let first_attempt_faults =
            faults.faults.iter().filter(|f| f.occurrence == 0).count() as u64;
        if first_attempt_faults > 0 {
            assert!(
                report.failures_detected > 0,
                "seed {seed} {}: faults at occurrence 0 but none detected",
                alg.name()
            );
        }
        for (name, expect) in names.iter().zip(&contents) {
            let got = dst.get(name).unwrap_or_else(|| panic!("seed {seed}: missing {name}"));
            assert_eq!(&got, expect, "seed {seed} {}: delivered bytes differ", alg.name());
        }
    }
}

/// FIVER-Merkle repair-loop convergence when the repair itself is
/// corrupted: round 1's re-sent leaf is struck again (occurrence 1), so a
/// second round must repair it — no ping-pong, intact delivery.
#[test]
fn merkle_repair_loop_converges_on_corrupted_repair() {
    let size = 500_000usize;
    let offset = 200_000u64;
    let faults = FaultPlan {
        faults: vec![
            Fault { file_idx: 0, offset, bit: 2, occurrence: 0 },
            Fault { file_idx: 0, offset: offset + 10, bit: 5, occurrence: 1 },
        ],
        crash: None,
    };
    let src = MemStorage::new();
    let mut data = vec![0u8; size];
    SplitMix64::new(0xC0FFEE).fill_bytes(&mut data);
    src.put("m", data.clone());
    let dst = MemStorage::new();
    let mut cfg =
        SessionConfig::new(RealAlgorithm::FiverMerkle, native_factory(HashAlgorithm::Fvr256));
    cfg.leaf_size = 32_768;
    let (report, rreport) = run_local_transfer(
        &["m".into()],
        Arc::new(src),
        Arc::new(dst.clone()),
        &cfg,
        &faults,
    )
    .unwrap();
    assert_eq!(dst.get("m").unwrap(), data, "delivered bytes differ");
    assert_eq!(report.repair_rounds, 2, "corrupted repair must trigger a second round");
    assert_eq!(report.failures_detected, 2, "two mismatched root exchanges");
    assert_eq!(rreport.units_failed, 2);
    // Both rounds re-send one 32 KiB leaf, not the 500 KB file.
    assert!(
        report.bytes_resent <= 2 * cfg.leaf_size,
        "bytes_resent {} should be <= 2 leaves",
        report.bytes_resent
    );
    assert_eq!(report.bytes_reread, report.bytes_resent);
}

/// PROPERTY (tiered hashing): a single flipped bit at a random offset is
/// always detected and *leaf-localized* by the tiered pipeline (XXH3-128
/// leaves under the cryptographic Merkle root) — and the detection and
/// repair accounting matches a pure-cryptographic run of the same seed
/// exactly. The fast leaf tier must not change what gets caught or how
/// much gets re-sent, only how fast the leaves hash.
#[test]
fn prop_tiered_detects_and_localizes_bit_flips() {
    for seed in 0..10u64 {
        let mut rng = SplitMix64::new(seed * 6151 + 0x71E6);
        let size = rng.range(100_000, 1_200_000) as usize;
        // Bias toward the edges (first/last leaf) — the risky spots.
        let offset = match rng.below(4) {
            0 => 0,
            1 => size as u64 - 1,
            _ => rng.below(size as u64),
        };
        let bit = rng.below(8) as u8;
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);

        let run = |tier: HashTier| {
            let faults = FaultPlan::at(0, offset, bit);
            let src = MemStorage::new();
            src.put("t", data.clone());
            let dst = MemStorage::new();
            let mut cfg = SessionConfig::new(
                RealAlgorithm::FiverMerkle,
                native_factory(HashAlgorithm::Sha1),
            );
            cfg.leaf_size = 32_768;
            cfg.hash_tier = tier;
            let (report, _) = run_local_transfer(
                &["t".into()],
                Arc::new(src),
                Arc::new(dst.clone()),
                &cfg,
                &faults,
            )
            .unwrap_or_else(|e| panic!("seed {seed} ({}): {e:#}", tier.name()));
            assert_eq!(
                dst.get("t").unwrap(),
                data,
                "seed {seed} ({}): delivered bytes differ",
                tier.name()
            );
            assert_eq!(
                report.failures_detected, 1,
                "seed {seed} ({}): bit flip at {offset} not detected",
                tier.name()
            );
            // Leaf localization: one flipped bit repairs one leaf, never
            // the whole file.
            assert!(
                report.bytes_resent <= cfg.leaf_size,
                "seed {seed} ({}): resent {} > one leaf",
                tier.name(),
                report.bytes_resent
            );
            (report.failures_detected, report.repair_rounds, report.bytes_resent)
        };
        let tiered = run(HashTier::Tiered);
        let crypto = run(HashTier::Cryptographic);
        assert_eq!(
            tiered, crypto,
            "seed {seed}: tiered and cryptographic repair accounting must match"
        );
    }
}

/// PROPERTY: the queue preserves the exact byte stream (order + content)
/// under arbitrary buffer-size interleavings and back-pressure.
#[test]
fn prop_queue_stream_integrity() {
    for seed in 0..20u64 {
        let mut rng = SplitMix64::new(seed + 0x9000);
        let cap = rng.range(64, 8192) as usize;
        let total = rng.range(1_000, 200_000) as usize;
        let q = ByteQueue::new(cap);
        let mut stream = vec![0u8; total];
        rng.fill_bytes(&mut stream);
        let expect = stream.clone();
        let q2 = q.clone();
        let mut chunk_rng = rng.fork();
        let producer = std::thread::spawn(move || {
            let mut pos = 0;
            while pos < stream.len() {
                let n = (chunk_rng.range(1, 4096) as usize).min(stream.len() - pos);
                assert!(q2.add(stream[pos..pos + n].to_vec().into()));
                pos += n;
            }
            q2.close();
        });
        let mut got = Vec::with_capacity(total);
        while let Some(buf) = q.remove() {
            got.extend_from_slice(&buf);
        }
        producer.join().unwrap();
        assert_eq!(got, expect, "seed {seed}");
    }
}

/// PROPERTY: units_of always partitions [0, size) exactly: contiguous,
/// non-overlapping, complete, and every unit except the last is full-size.
#[test]
fn prop_units_partition() {
    for seed in 0..50u64 {
        let mut rng = SplitMix64::new(seed + 0xBEE);
        let mut cfg =
            SessionConfig::new(RealAlgorithm::FiverChunk, native_factory(HashAlgorithm::Md5));
        cfg.block_size = rng.range(1, 1 << 20);
        let size = rng.below(1 << 24);
        let units = cfg.units_of(size, true);
        assert!(!units.is_empty());
        let mut expect_offset = 0u64;
        for (i, &(id, offset, len)) in units.iter().enumerate() {
            assert_eq!(id, i as u64, "seed {seed}");
            assert_eq!(offset, expect_offset, "seed {seed}");
            if i + 1 < units.len() {
                assert_eq!(len, cfg.block_size, "seed {seed}: non-final unit full");
            }
            expect_offset += len;
        }
        assert_eq!(expect_offset, size, "seed {seed}: covers the file");
    }
}

/// PROPERTY: whole-file modes always produce exactly one unit with the
/// sentinel id.
#[test]
fn prop_whole_file_unit() {
    for seed in 0..20u64 {
        let mut rng = SplitMix64::new(seed);
        let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Md5));
        let size = rng.below(1 << 30);
        assert_eq!(cfg.units_of(size, true), vec![(protocol::UNIT_FILE, 0, size)]);
    }
}

/// PROPERTY: protocol frames round-trip through a byte stream for random
/// contents.
#[test]
fn prop_protocol_roundtrip() {
    use protocol::Frame;
    for seed in 0..30u64 {
        let mut rng = SplitMix64::new(seed + 0x3C0);
        let mut payload = vec![0u8; rng.below(10_000) as usize];
        rng.fill_bytes(&mut payload);
        let frames = vec![
            Frame::FileStart {
                file_idx: rng.next_u32(),
                size: rng.next_u64(),
                attempt: rng.below(5),
                name: format!("n{}", rng.next_u32()),
            },
            Frame::Data {
                file_idx: rng.next_u32(),
                offset: rng.next_u64(),
                payload: payload.clone().into(),
            },
            Frame::Digest {
                file_idx: rng.next_u32(),
                unit: rng.next_u64(),
                digest: payload.clone(),
            },
            Frame::Verdict {
                file_idx: rng.next_u32(),
                unit: rng.next_u64(),
                ok: rng.below(2) == 1,
            },
            Frame::Fix {
                file_idx: rng.next_u32(),
                offset: rng.next_u64(),
                payload: payload.into(),
            },
            Frame::Done,
        ];
        let mut buf = Vec::new();
        for f in &frames {
            f.write_to(&mut buf).unwrap();
        }
        let mut cursor = &buf[..];
        for f in &frames {
            let back = Frame::read_from(&mut cursor).unwrap().unwrap();
            assert_eq!(&back, f, "seed {seed}");
        }
        assert!(Frame::read_from(&mut cursor).unwrap().is_none());
    }
}

/// PROPERTY: a fault on the wire NEVER survives into the destination file
/// (fail-closed), across random single-fault positions including
/// chunk-boundary-adjacent offsets.
#[test]
fn prop_single_fault_never_survives() {
    for seed in 0..10u64 {
        let mut rng = SplitMix64::new(seed + 0xFA17);
        let size = 600_000usize;
        let block = 200_000u64;
        // Bias offsets toward unit boundaries (the risky spots).
        let offset = match rng.below(4) {
            0 => 0,
            1 => block - 1,
            2 => block,
            _ => rng.below(size as u64),
        };
        let faults = FaultPlan::at(0, offset, rng.below(8) as u8);
        let src = MemStorage::new();
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        src.put("f", data.clone());
        let dst = MemStorage::new();
        let mut cfg =
            SessionConfig::new(RealAlgorithm::FiverChunk, native_factory(HashAlgorithm::Fvr256));
        cfg.block_size = block;
        let (report, _) =
            run_local_transfer(&["f".into()], Arc::new(src), Arc::new(dst.clone()), &cfg, &faults)
                .unwrap();
        assert_eq!(report.failures_detected, 1, "seed {seed} offset {offset}");
        assert_eq!(dst.get("f").unwrap(), data, "seed {seed} offset {offset}");
    }
}
