//! Allocation regression gate for the zero-copy data plane: a transfer's
//! heap traffic must scale with the buffer *pool* (O(pool) warmup), not
//! with the number of chunks moved.
//!
//! Method: a `#[global_allocator]` shim counts allocation events and
//! bytes, and we compare a 16 MB and a 64 MB single-file FIVER transfer
//! over loopback TCP with FsStorage on both ends (identical
//! thread/session structure; only the chunk count differs: 64 vs 256
//! chunks at 256 KiB).
//!
//! What the pooled plane still pays per chunk is two constant-size
//! `Arc<Backing>` control blocks (sender freeze + receiver decode,
//! ~100 B each) plus mpsc's amortized block allocation — versus the two
//! fresh *zeroed 256 KiB* `Vec`s per chunk of the owned plane. The
//! discriminating assertion is therefore on **bytes**: the pre-pool plane
//! allocated ~2 × buf_size per chunk (~512 KiB); the pooled plane must
//! stay under buf_size/16 per chunk (16 KiB — 60x headroom over the
//! expected ~250 B, and 32x below the old cost). A looser event-count
//! ceiling guards against reintroducing per-chunk Vec churn on top of
//! the refcount residue.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use fiver::coordinator::session::run_local_transfer;
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::HashAlgorithm;
use fiver::storage::{FsStorage, Storage};
use fiver::util::rng::SplitMix64;
use fiver::util::tmpdir::TempDir;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Counts allocation events and bytes (alloc + realloc); frees are
/// irrelevant to the O(pool)-vs-O(chunks) question.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const BUF_SIZE: usize = 256 * 1024;

/// Run one single-file FIVER loopback transfer over FsStorage and return
/// (allocation events, allocated bytes) for the transfer itself.
fn transfer_cost(base: &TempDir, tag: &str, size: usize) -> (u64, u64) {
    let src_dir = base.join(&format!("src-{tag}"));
    let dst_dir = base.join(&format!("dst-{tag}"));
    let src = FsStorage::new(&src_dir).expect("src storage");
    {
        let mut data = vec![0u8; size];
        SplitMix64::new(size as u64).fill_bytes(&mut data);
        let mut w = src.open_write("f").expect("create source file");
        w.write_next(&data).expect("write source file");
        w.flush().expect("flush source file");
    }
    let mut cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Md5));
    // Tracing ON: the observability plane claims allocation-free steady
    // state (preallocated span rings, fixed-bucket histograms), so it must
    // pass the same O(pool)-not-O(chunks) gate as the data plane. Span
    // volume scales with chunk count — any per-span allocation would blow
    // the byte budget immediately.
    cfg.obs = fiver::obs::Recorder::enabled();
    cfg.buf_size = BUF_SIZE;
    // Pin the pool well below the transfer's demand so every run
    // saturates it: each endpoint allocates exactly `pool_buffers`
    // backings regardless of scheduling, making the backing-allocation
    // cost identical across runs (lazy sizing would otherwise add
    // +-few x 256 KiB of run-to-run noise to the byte delta). The
    // producer then simply blocks until the hash worker returns a buffer
    // — pool-level backpressure, still zero fallback allocations.
    cfg.pool_buffers = 8;
    let names = vec!["f".to_string()];
    let src: Arc<dyn Storage> = Arc::new(src);
    let dst: Arc<dyn Storage> = Arc::new(FsStorage::new(&dst_dir).expect("dst storage"));

    let events_before = ALLOCS.load(Ordering::SeqCst);
    let bytes_before = ALLOC_BYTES.load(Ordering::SeqCst);
    let (report, receiver) =
        run_local_transfer(&names, src, dst, &cfg, &FaultPlan::none()).expect("transfer");
    let events = ALLOCS.load(Ordering::SeqCst) - events_before;
    let bytes = ALLOC_BYTES.load(Ordering::SeqCst) - bytes_before;
    assert_eq!(report.bytes_sent, size as u64);
    assert_eq!(receiver.units_failed, 0);
    (events, bytes)
}

#[test]
fn steady_state_allocations_scale_with_pool_not_chunks() {
    let base = TempDir::create("fiver-allocgate").expect("tempdir");
    // Warm up allocator arenas, lazy statics and thread machinery so the
    // measured runs differ only in chunk count.
    transfer_cost(&base, "warmup", 4 << 20);

    let small = 16usize << 20;
    let large = 64usize << 20;
    let (ev_small, by_small) = transfer_cost(&base, "small", small);
    let (ev_large, by_large) = transfer_cost(&base, "large", large);
    let chunk_delta = ((large - small) / BUF_SIZE) as u64; // 192 extra chunks

    // Bytes: the discriminator. Owned-Vec plane: ~2 x 256 KiB per chunk.
    // Pooled plane: ~250 B per chunk. Budget: 16 KiB per chunk — 60x
    // over the expected residue (headroom for a rare scheduler-stall
    // fallback allocation), 32x under the owned plane's cost.
    let byte_delta = by_large.saturating_sub(by_small);
    let byte_budget = chunk_delta * (BUF_SIZE as u64 / 16);
    assert!(
        byte_delta < byte_budget,
        "heap bytes scale with chunks: {by_small} B at 16 MB vs {by_large} B at 64 MB \
         (delta {byte_delta} B for {chunk_delta} extra chunks, budget {byte_budget} B — \
         payload buffers must recycle through the pool, not reallocate per chunk)"
    );

    // Events: a ceiling over the known per-chunk residue (two refcount
    // blocks + amortized channel blocks), guarding against reintroduced
    // per-chunk Vec churn on top of it.
    let event_delta = ev_large.saturating_sub(ev_small);
    assert!(
        event_delta < chunk_delta * 3,
        "allocation events scale past the refcount residue: {ev_small} at 16 MB vs \
         {ev_large} at 64 MB (delta {event_delta} for {chunk_delta} extra chunks)"
    );
}
