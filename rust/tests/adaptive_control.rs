//! Integration: the adaptive concurrency controller must never change
//! *what* a transfer delivers — only how fast. Bit-identical delivery
//! under live pool/stripe actuation (with fault repair in flight),
//! across a crash/resume cycle, and a report surface that is unchanged
//! (modulo an empty `adaptations` list) when the controller is off.

use std::sync::Arc;

use fiver::coordinator::scheduler::EngineConfig;
use fiver::coordinator::session::{
    run_parallel_local_transfer, run_recoverable_local_transfer,
};
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::{Fault, FaultPlan};
use fiver::hashes::HashAlgorithm;
use fiver::obs::Recorder;
use fiver::storage::MemStorage;
use fiver::util::rng::SplitMix64;
use fiver::util::tmpdir::TempDir;

/// Build an in-memory source with the given pseudo-random file sizes.
fn mem_src(sizes: &[usize], rng: &mut SplitMix64) -> (MemStorage, Vec<String>, Vec<Vec<u8>>) {
    let storage = MemStorage::new();
    let mut names = Vec::new();
    let mut contents = Vec::new();
    for (i, &size) in sizes.iter().enumerate() {
        let mut data = vec![0u8; size];
        rng.fork().fill_bytes(&mut data);
        let name = format!("a{i:03}");
        storage.put(&name, data.clone());
        names.push(name);
        contents.push(data);
    }
    (storage, names, contents)
}

/// An aggressive controller config: tiny sample window so even a short
/// test transfer spans many decision opportunities.
fn adaptive_cfg(alg: RealAlgorithm) -> SessionConfig {
    let mut cfg = SessionConfig::new(alg, native_factory(HashAlgorithm::Fvr256));
    cfg.obs = Recorder::enabled(); // the controller samples the recorder
    cfg.control.adaptive = true;
    cfg.control.interval_ms = 2;
    cfg.control.max_parallel = 4;
    cfg.control.max_hash_workers = 4;
    cfg
}

/// PROPERTY: with the controller live (sampling every 2 ms, free to
/// grow/retire hash workers and re-latch the stripe count at every file
/// boundary) and a bit-fault striking mid-stream, delivery stays
/// bit-identical and the fault is still detected and repaired — the
/// control plane must be invisible to correctness. Every recorded
/// decision respects the configured ceilings.
#[test]
fn adaptive_transfer_is_bit_identical_under_faults() {
    for (seed, alg) in [(1u64, RealAlgorithm::Fiver), (2, RealAlgorithm::FiverMerkle)] {
        let mut rng = SplitMix64::new(seed * 7919 + 3);
        let n_files = rng.range(3, 6) as usize;
        let sizes: Vec<usize> =
            (0..n_files).map(|_| rng.range(10_000, 200_000) as usize).collect();
        let (src, names, contents) = mem_src(&sizes, &mut rng);
        let dst = MemStorage::new();
        let cfg = adaptive_cfg(alg);
        let eng = EngineConfig {
            concurrency: 2,
            parallel: 2,
            hash_workers: 1, // misconfigured on purpose: the controller may grow it
            batch_threshold: 0,
            batch_bytes: 1,
        };
        let faults = FaultPlan {
            faults: vec![Fault {
                file_idx: 0,
                offset: (sizes[0] / 2) as u64,
                bit: 3,
                occurrence: 0,
            }],
            crash: None,
        };
        let (report, rreports) = run_parallel_local_transfer(
            &names,
            Arc::new(src),
            Arc::new(dst.clone()),
            &cfg,
            &eng,
            &faults,
        )
        .unwrap_or_else(|e| panic!("seed {seed} {}: adaptive run failed: {e:#}", alg.name()));
        assert_eq!(rreports.len(), eng.concurrency);
        for (name, expect) in names.iter().zip(&contents) {
            assert_eq!(
                &dst.get(name).unwrap(),
                expect,
                "seed {seed} {}: delivered bytes differ on {name}",
                alg.name()
            );
        }
        let totals = report.aggregate();
        assert!(
            totals.failures_detected >= 1,
            "seed {seed} {}: planted fault never detected",
            alg.name()
        );
        for ev in &report.adaptations {
            match ev.actuator {
                "hash_workers" => assert!(
                    (1..=cfg.control.max_hash_workers).contains(&ev.after),
                    "seed {seed}: pool target {} out of bounds: {ev:?}",
                    ev.after
                ),
                "stripes" => assert!(
                    (1..=cfg.control.max_parallel.max(eng.parallel)).contains(&ev.after),
                    "seed {seed}: stripe target {} out of bounds: {ev:?}",
                    ev.after
                ),
                other => panic!("seed {seed}: unknown actuator {other}"),
            }
        }
    }
}

/// The crash/resume cycle with the controller live on both attempts:
/// kill mid-dataset, restart against the journals, and the delivered
/// bytes are still bit-identical with a clean (zero re-read) resume —
/// stripe re-latching and pool resizing must not perturb what the
/// journals attest.
#[test]
fn adaptive_crash_resume_stays_bit_identical() {
    let mut rng = SplitMix64::new(0xADA9);
    let sizes = [150_000usize, 80_000, 120_000];
    let total: u64 = sizes.iter().map(|&s| s as u64).sum();
    let (src, names, contents) = mem_src(&sizes, &mut rng);
    let dst = MemStorage::new();
    let jroot = TempDir::create("fiver-adaptive-crash").expect("scratch dir");
    let mut scfg = adaptive_cfg(RealAlgorithm::FiverMerkle);
    scfg.leaf_size = 16_384;
    scfg.buf_size = 16_384;
    scfg.journal_checkpoint_leaves = 1;
    scfg.journal_dir = Some(jroot.join("snd"));
    let mut rcfg = scfg.clone();
    rcfg.obs = Recorder::enabled(); // endpoints keep separate recorders
    rcfg.journal_dir = Some(jroot.join("rcv"));
    let eng = EngineConfig {
        concurrency: 2,
        parallel: 2,
        hash_workers: 1,
        batch_threshold: 0,
        batch_bytes: 1,
    };
    let crashed = run_recoverable_local_transfer(
        &names,
        Arc::new(src.clone()),
        Arc::new(dst.clone()),
        &scfg,
        &rcfg,
        &eng,
        &FaultPlan::none().with_crash_after_bytes(total / 2),
    );
    assert!(crashed.is_err(), "planned kill must abort the adaptive run");
    scfg.resume = true;
    rcfg.resume = true;
    let (report, _) = run_recoverable_local_transfer(
        &names,
        Arc::new(src),
        Arc::new(dst.clone()),
        &scfg,
        &rcfg,
        &eng,
        &FaultPlan::none(),
    )
    .unwrap_or_else(|e| panic!("adaptive resume failed: {e:#}"));
    let totals = report.aggregate();
    for (name, expect) in names.iter().zip(&contents) {
        assert_eq!(
            &dst.get(name).unwrap(),
            expect,
            "delivered bytes differ on {name} after adaptive resume"
        );
    }
    assert_eq!(totals.bytes_reread, 0, "clean resume must not re-read");
    assert_eq!(
        totals.bytes_sent + totals.bytes_skipped,
        total,
        "skip accounting must partition the dataset"
    );
}

/// With `--adaptive` off (the default) nothing changes: the engine
/// provisions exactly `--parallel` lanes, spawns no controller thread,
/// and the report is byte-for-byte what it was before the control plane
/// existed — the `adaptations` trail exists but is empty, on the engine
/// report, its aggregate, and every per-session report.
#[test]
fn disabled_controller_reports_have_empty_adaptations() {
    let mut rng = SplitMix64::new(0x0FF);
    let sizes = [60_000usize, 90_000, 40_000];
    let (src, names, contents) = mem_src(&sizes, &mut rng);
    let dst = MemStorage::new();
    let mut cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    // Explicitly off (not just defaulted) so the assertion holds even
    // under the CI leg that exports FIVER_ADAPTIVE=1.
    cfg.control.adaptive = false;
    let eng = EngineConfig {
        concurrency: 2,
        parallel: 2,
        hash_workers: 2,
        batch_threshold: 0,
        batch_bytes: 1,
    };
    let (report, _) = run_parallel_local_transfer(
        &names,
        Arc::new(src),
        Arc::new(dst.clone()),
        &cfg,
        &eng,
        &FaultPlan::none(),
    )
    .expect("non-adaptive run");
    for (name, expect) in names.iter().zip(&contents) {
        assert_eq!(&dst.get(name).unwrap(), expect, "delivery unchanged on {name}");
    }
    assert!(report.adaptations.is_empty(), "no controller, no decisions");
    assert!(report.aggregate().adaptations.is_empty());
    for s in &report.per_session {
        assert!(s.adaptations.is_empty(), "per-session reports never carry decisions");
    }
}
