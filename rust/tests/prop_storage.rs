//! Storage backend conformance property suite: every backend — the
//! in-memory one and each FsStorage engine (buffered / mmap / direct) —
//! must deliver byte-identical semantics under random interleavings of
//! the full trait surface: sequential writes, ranged (repair) writes,
//! scatter batches, sync/flush, reopen-for-update, and all three read
//! paths (`read_next`, `read_at`, `read_shared`).
//!
//! The model is a plain `Vec<u8>` with the shared cursor rule (ranged
//! writes only ever *raise* the sequential cursor to the end of their
//! range). Whatever the engine does underneath — pwrite, MAP_SHARED
//! stores + remap growth, O_DIRECT with per-op fallback — the observable
//! bytes must match the model exactly.

use std::sync::Arc;

use fiver::coordinator::bufpool::BufferPool;
use fiver::storage::{read_all, FsStorage, IoBackend, MemStorage, Storage, DIRECT_ALIGN};
use fiver::util::rng::SplitMix64;
use fiver::util::tmpdir::TempDir;

/// Every constructible backend under `dir`. Engines the platform or the
/// filesystem refuses degrade inside FsStorage — still exercised.
fn all_backends(dir: &TempDir) -> Vec<(String, Arc<dyn Storage>)> {
    let mut out: Vec<(String, Arc<dyn Storage>)> =
        vec![("mem".to_string(), Arc::new(MemStorage::new()))];
    for b in IoBackend::ALL {
        let sub = dir.join(b.name());
        let s = FsStorage::with_backend(&sub, b).expect("backend storage");
        out.push((format!("fs-{}", b.name()), Arc::new(s)));
    }
    out
}

/// In-memory model of one file plus the shared cursor rule.
#[derive(Default)]
struct Model {
    data: Vec<u8>,
    pos: u64,
}

impl Model {
    fn write_at(&mut self, offset: u64, bytes: &[u8]) {
        if !bytes.is_empty() {
            let end = offset as usize + bytes.len();
            if self.data.len() < end {
                self.data.resize(end, 0);
            }
            self.data[offset as usize..end].copy_from_slice(bytes);
        }
        // Empty ranged writes still raise the cursor (the shared rule).
        self.pos = self.pos.max(offset + bytes.len() as u64);
    }

    fn write_next(&mut self, bytes: &[u8]) {
        let pos = self.pos;
        let end = pos as usize + bytes.len();
        if self.data.len() < end {
            self.data.resize(end, 0);
        }
        self.data[pos as usize..end].copy_from_slice(bytes);
        self.pos = pos + bytes.len() as u64;
    }
}

fn rand_bytes(rng: &mut SplitMix64, len: usize) -> Vec<u8> {
    let mut v = vec![0u8; len];
    rng.fill_bytes(&mut v);
    v
}

/// PROPERTY: random interleavings of write_next / write_at / scatter
/// write_at_vectored / flush / sync, then a reopen-for-update repair
/// phase, leave every backend holding exactly the model's bytes — and
/// all three read paths agree with the model at random offsets.
#[test]
fn prop_random_interleavings_read_back_byte_identical() {
    let pool = BufferPool::with_options(64 * 1024, 4, DIRECT_ALIGN, 4);
    for seed in 0..10u64 {
        let dir = TempDir::create("fiver-propstorage").expect("scratch dir");
        for (name, storage) in all_backends(&dir) {
            let mut rng = SplitMix64::new(seed * 0x9E37 + 0x79B9);
            let mut model = Model::default();
            let file = "f0";
            // Phase 1: streaming writes, sometimes pre-sized (the
            // receiver's FileStart hint), sometimes not.
            let hint = rng.range(0, 300_000);
            let mut w = if rng.below(2) == 0 {
                storage.open_write(file).expect("open_write")
            } else {
                storage.open_write_sized(file, hint).expect("open_write_sized")
            };
            let ops = rng.range(10, 40);
            for _ in 0..ops {
                match rng.below(6) {
                    0 | 1 | 2 => {
                        // Sequential stream chunk (the common case).
                        let len = rng.range(1, 50_000) as usize;
                        let bytes = rand_bytes(&mut rng, len);
                        w.write_next(&bytes).expect("write_next");
                        model.write_next(&bytes);
                    }
                    3 => {
                        // Ranged (repair-style) write, possibly past EOF —
                        // occasionally empty (raises the cursor only).
                        let cap = model.data.len() as u64 + 10_000;
                        let offset = rng.range(0, cap.max(1));
                        let len = rng.range(0, 20_000) as usize;
                        let bytes = rand_bytes(&mut rng, len);
                        w.write_at(offset, &bytes).expect("write_at");
                        model.write_at(offset, &bytes);
                    }
                    4 => {
                        // Scatter batch of adjacent parts.
                        let cap = model.data.len() as u64 + 5_000;
                        let offset = rng.range(0, cap.max(1));
                        let parts: Vec<Vec<u8>> = (0..rng.range(1, 4))
                            .map(|_| rand_bytes(&mut rng, rng.range(1, 8_000) as usize))
                            .collect();
                        let slices: Vec<&[u8]> = parts.iter().map(|p| &p[..]).collect();
                        w.write_at_vectored(offset, &slices).expect("write_at_vectored");
                        let mut off = offset;
                        for p in &parts {
                            model.write_at(off, p);
                            off += p.len() as u64;
                        }
                    }
                    _ => {
                        // Durability points interleave with the stream.
                        if rng.below(2) == 0 {
                            w.flush().expect("flush");
                        } else {
                            w.sync().expect("sync");
                        }
                    }
                }
            }
            w.flush().expect("final flush");
            drop(w);
            assert_eq!(
                storage.size_of(file).expect("size_of"),
                model.data.len() as u64,
                "seed {seed} {name}: size after phase 1"
            );

            // Phase 2: reopen for update (the Fix-writer path) and patch.
            if !model.data.is_empty() {
                let mut u = storage.open_update(file).expect("open_update");
                for _ in 0..rng.range(1, 6) {
                    let offset = rng.below(model.data.len() as u64);
                    let len = rng
                        .range(1, 10_000)
                        .min(model.data.len() as u64 - offset) as usize;
                    let bytes = rand_bytes(&mut rng, len);
                    u.write_at(offset, &bytes).expect("repair write_at");
                    model.write_at(offset, &bytes);
                }
                u.sync().expect("repair sync");
                drop(u);
            }
            assert_eq!(
                storage.size_of(file).expect("size_of"),
                model.data.len() as u64,
                "seed {seed} {name}: repairs must not change the length"
            );

            // Read-back: full sequential, then random ranged + shared.
            let back = read_all(&storage, file).expect("read_all");
            assert_eq!(back, model.data, "seed {seed} {name}: full read-back");
            let mut r = storage.open_read(file).expect("open_read");
            for _ in 0..8 {
                if model.data.is_empty() {
                    break;
                }
                let offset = rng.below(model.data.len() as u64);
                let want = rng.range(1, 70_000) as usize;
                let mut buf = vec![0u8; want];
                let n = r.read_at(offset, &mut buf).expect("read_at");
                let expect_n = want.min(model.data.len() - offset as usize);
                assert_eq!(n, expect_n, "seed {seed} {name}: read_at length at {offset}");
                assert_eq!(
                    &buf[..n],
                    &model.data[offset as usize..offset as usize + n],
                    "seed {seed} {name}: read_at bytes at {offset}"
                );
                let shared = r.read_shared(offset, want, &pool).expect("read_shared");
                assert!(
                    !shared.is_empty() && shared.len() <= want,
                    "seed {seed} {name}: read_shared progress at {offset}"
                );
                assert_eq!(
                    &shared[..],
                    &model.data[offset as usize..offset as usize + shared.len()],
                    "seed {seed} {name}: read_shared bytes at {offset}"
                );
            }
        }
    }
}

/// The repair pattern every backend must preserve exactly: ranged writes
/// interleaved with a sequential stream never disturb the stream cursor,
/// and `sync` mid-stream leaves the bytes readable by a fresh reader
/// (the journal's data-before-watermark read-back).
#[test]
fn midstream_sync_is_readable_by_a_fresh_reader() {
    let dir = TempDir::create("fiver-propsync").expect("scratch dir");
    for (name, storage) in all_backends(&dir) {
        let mut w = storage.open_write_sized("f", 200_000).expect("open");
        let first = vec![0xA1u8; 70_000];
        w.write_next(&first).expect("write");
        w.sync().expect("sync");
        // A fresh reader (different descriptor / mapping) must see the
        // synced prefix even while the writer stays open — exactly what
        // Storage::sync_file + journal checkpointing rely on.
        let got = {
            let mut r = storage.open_read("f").expect("read");
            let mut buf = vec![0u8; 70_000];
            let mut filled = 0;
            while filled < buf.len() {
                let n = r.read_next(&mut buf[filled..]).expect("read_next");
                if n == 0 {
                    break;
                }
                filled += n;
            }
            buf.truncate(filled);
            buf
        };
        assert!(got.len() >= 70_000, "{name}: synced prefix visible to a fresh reader");
        assert_eq!(&got[..70_000], &first[..], "{name}: synced prefix bytes");
        w.write_next(&[0xB2u8; 30_000]).expect("tail");
        w.flush().expect("flush");
        drop(w);
        assert_eq!(storage.size_of("f").expect("size"), 100_000, "{name}");
        let back = read_all(&storage, "f").expect("read_all");
        assert_eq!(&back[..70_000], &first[..], "{name}");
        assert_eq!(&back[70_000..], &[0xB2u8; 30_000][..], "{name}");
    }
}

/// `sync_file` (the hash-job checkpoint's data sync) must work while a
/// writer holds the file open on every backend — including mmap, where
/// the dirty pages live in a MAP_SHARED mapping owned by the writer.
#[test]
fn sync_file_while_writer_open_every_backend() {
    let dir = TempDir::create("fiver-propsyncfile").expect("scratch dir");
    for (name, storage) in all_backends(&dir) {
        let mut w = storage.open_write_sized("f", 50_000).expect("open");
        w.write_next(&[0x5Au8; 50_000]).expect("write");
        let before = storage.sync_count();
        storage.sync_file("f").expect("sync_file with writer open");
        assert!(storage.sync_count() > before, "{name}: sync_file must count");
        w.flush().expect("flush");
        drop(w);
        let back = read_all(&storage, "f").expect("read_all");
        assert_eq!(back, vec![0x5Au8; 50_000], "{name}");
    }
}

/// Serializes the tests that toggle `FIVER_URING_DISABLE`: the variable
/// is process-global, so concurrently running env-sensitive tests would
/// observe each other's settings.
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Forcing the ring off (`FIVER_URING_DISABLE=1`) must degrade a whole
/// uring-backend transfer to the buffered engine — counted exactly once
/// per storage — while the delivered bytes stay bit-identical. This is
/// the degradation path every kernel without io_uring takes implicitly;
/// the env override makes it deterministic everywhere.
#[test]
fn uring_forced_fallback_transfer_is_buffered_and_counted() {
    use fiver::coordinator::session::run_local_transfer;
    use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
    use fiver::faults::FaultPlan;
    use fiver::hashes::HashAlgorithm;

    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::set_var("FIVER_URING_DISABLE", "1");
    let dir = TempDir::create("fiver-uringfb").expect("scratch dir");
    let src = FsStorage::with_backend(&dir.join("src"), IoBackend::Uring).expect("src");
    let mut rng = SplitMix64::new(7);
    let data = rand_bytes(&mut rng, 300_000);
    {
        let mut w = src.open_write("f").expect("open");
        w.write_next(&data).expect("write");
        w.flush().expect("flush");
    }
    let src: Arc<dyn Storage> = Arc::new(src);
    let dst: Arc<dyn Storage> =
        Arc::new(FsStorage::with_backend(&dir.join("dst"), IoBackend::Uring).expect("dst"));
    let mut cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    cfg.io_backend = IoBackend::Uring;
    let names = vec!["f".to_string()];
    let (report, _) =
        run_local_transfer(&names, src.clone(), dst.clone(), &cfg, &FaultPlan::none())
            .expect("transfer under forced fallback");
    assert_eq!(report.uring_fallbacks, 1, "ring refusal is counted once per storage");
    let back = read_all(&dst, "f").expect("read_all");
    assert_eq!(back, data, "fallback delivery must stay bit-identical");

    // Second wave over the *same* storages, ring still forced off: the
    // setup refusal was already counted and cached, so streaming three
    // more files must not move the counter — it is once per storage,
    // never per file, per stream, or per transfer wave.
    let mut rng2 = SplitMix64::new(8);
    let more: Vec<(String, Vec<u8>)> =
        (0..3).map(|i| (format!("g{i}"), rand_bytes(&mut rng2, 120_000))).collect();
    for (name, bytes) in &more {
        let mut w = src.open_write(name).expect("open wave 2");
        w.write_next(bytes).expect("write wave 2");
        w.flush().expect("flush wave 2");
    }
    let names2: Vec<String> = more.iter().map(|(n, _)| n.clone()).collect();
    let (report2, _) = run_local_transfer(&names2, src, dst.clone(), &cfg, &FaultPlan::none())
        .expect("second wave under forced fallback");
    std::env::remove_var("FIVER_URING_DISABLE");
    assert_eq!(
        report2.uring_fallbacks, 1,
        "multi-wave, multi-file reuse must never re-count the refusal"
    );
    for (name, bytes) in &more {
        assert_eq!(&read_all(&dst, name).expect("read_all"), bytes, "{name} bit-identical");
    }
}

/// `auto` under a disabled ring degrades to the direct engine for every
/// file at/above the threshold, and the refused ring setup still counts
/// exactly one uring fallback for the storage no matter how many files
/// resolve through it.
#[cfg(target_os = "linux")]
#[test]
fn uring_disable_under_auto_counts_one_fallback_per_storage() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::set_var("FIVER_URING_DISABLE", "1");
    let dir = TempDir::create("fiver-autodisable").expect("scratch dir");
    let fs = FsStorage::with_backend(&dir.join("root"), IoBackend::Auto)
        .expect("auto storage")
        .with_threshold(0);
    for name in ["a", "b"] {
        let mut w = fs.open_write(name).expect("open");
        w.write_next(&[7u8; 4096]).expect("write");
        w.flush().expect("flush");
    }
    assert_eq!(fs.backend_for("a"), "direct", "ringless auto degrades to direct");
    assert_eq!(fs.backend_for("b"), "direct");
    assert_eq!(fs.backend_for("a"), "direct", "re-resolving stays direct");
    std::env::remove_var("FIVER_URING_DISABLE");
    assert_eq!(fs.uring_fallbacks(), 1, "one refused ring setup, one fallback");
}

/// `--io-backend auto`'s boundary is pinned at exactly
/// `--direct-threshold`: a file of the threshold size routes to
/// uring/direct, one byte less stays buffered. (Regression: the boundary
/// must be `size >= threshold`, not `>`.)
#[test]
fn auto_backend_boundary_is_pinned_at_the_threshold() {
    const T: u64 = 8192;
    let dir = TempDir::create("fiver-autoboundary").expect("scratch dir");
    let fs = FsStorage::with_backend(&dir.join("root"), IoBackend::Auto)
        .expect("auto storage")
        .with_threshold(T);
    for (name, size) in [("below", T - 1), ("at", T), ("above", T + 1)] {
        let mut w = fs.open_write(name).expect("open");
        w.write_next(&vec![0x3Cu8; size as usize]).expect("write");
        w.flush().expect("flush");
    }
    assert_eq!(fs.backend_for("below"), "buffered", "one byte under the threshold");
    if cfg!(target_os = "linux") {
        assert_ne!(fs.backend_for("at"), "buffered", "exactly the threshold is inclusive");
        assert_ne!(fs.backend_for("above"), "buffered");
    }
}

/// `--direct-threshold 0` means *always* uring/direct under `auto` —
/// even a zero-byte (or not-yet-written) file satisfies `size >= 0`.
#[cfg(target_os = "linux")]
#[test]
fn auto_threshold_zero_always_routes_past_buffered() {
    let dir = TempDir::create("fiver-autozero").expect("scratch dir");
    let fs = FsStorage::with_backend(&dir.join("root"), IoBackend::Auto)
        .expect("auto storage")
        .with_threshold(0);
    let mut w = fs.open_write("tiny").expect("open");
    w.write_next(&[1u8; 16]).expect("write");
    w.flush().expect("flush");
    drop(w);
    assert_ne!(fs.backend_for("tiny"), "buffered", "threshold 0 never buffers");
    assert_ne!(fs.backend_for("missing"), "buffered", "size 0 >= threshold 0");
}
