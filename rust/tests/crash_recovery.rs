//! Deterministic crash-injection harness: kill the engine at an
//! arbitrary data-frame boundary (chosen per seed), restart it against
//! the checkpoint journals, and require bit-identical delivery for every
//! algorithm at N×P concurrency — with the resume machinery re-reading at
//! most one Merkle leaf per file that was open at the crash (zero in a
//! clean resume: the prefix proof is pure digest folding). Crash
//! recovery is a regression-gated invariant here, not a demo.

use std::sync::Arc;

use fiver::coordinator::journal::Journal;
use fiver::coordinator::scheduler::EngineConfig;
use fiver::coordinator::session::run_recoverable_local_transfer;
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::{Fault, FaultPlan};
use fiver::hashes::HashAlgorithm;
use fiver::storage::MemStorage;
use fiver::util::rng::SplitMix64;
use fiver::util::tmpdir::TempDir;

/// Build an in-memory source with the given pseudo-random file sizes.
fn mem_src(sizes: &[usize], rng: &mut SplitMix64) -> (MemStorage, Vec<String>, Vec<Vec<u8>>) {
    let storage = MemStorage::new();
    let mut names = Vec::new();
    let mut contents = Vec::new();
    for (i, &size) in sizes.iter().enumerate() {
        let mut data = vec![0u8; size];
        rng.fork().fill_bytes(&mut data);
        let name = format!("k{i:03}");
        storage.put(&name, data.clone());
        names.push(name);
        contents.push(data);
    }
    (storage, names, contents)
}

/// Journaled sender/receiver configs under `root` ("snd" / "rcv").
fn journaled_cfgs(
    alg: RealAlgorithm,
    root: &TempDir,
    leaf_size: u64,
) -> (SessionConfig, SessionConfig) {
    let mut scfg = SessionConfig::new(alg, native_factory(HashAlgorithm::Fvr256));
    scfg.leaf_size = leaf_size;
    scfg.journal_dir = Some(root.join("snd"));
    let mut rcfg = scfg.clone();
    rcfg.journal_dir = Some(root.join("rcv"));
    (scfg, rcfg)
}

/// PROPERTY: any dataset + any crash point + any algorithm, at N×P >= 2
/// concurrency => the journal-resumed restart delivers every file
/// bit-identical, re-reads nothing for the verified prefix
/// (`bytes_reread == 0` in a clean resume), and sends exactly the bytes
/// the journals could not prove delivered.
#[test]
fn prop_crash_resume_bit_identical_all_algorithms() {
    for seed in 0..5u64 {
        let mut rng = SplitMix64::new(seed * 14407 + 11);
        for alg in RealAlgorithm::ALL {
            let n_files = rng.range(3, 7) as usize;
            let mut sizes = Vec::new();
            for _ in 0..n_files {
                let size = match rng.below(4) {
                    0 => 0,
                    1 => rng.range(1, 2_000),
                    2 => rng.range(20_000, 90_000),
                    _ => rng.range(90_000, 300_000),
                };
                sizes.push(size as usize);
            }
            let total: u64 = sizes.iter().map(|&s| s as u64).sum();
            if total == 0 {
                continue;
            }
            let (src, names, contents) = mem_src(&sizes, &mut rng);
            let dst = MemStorage::new();
            let jroot = TempDir::create("fiver-crash").expect("scratch dir");
            let (mut scfg, mut rcfg) = journaled_cfgs(alg, &jroot, 16_384);
            for cfg in [&mut scfg, &mut rcfg] {
                // >= 8 KiB buffers take the vectored write path, so frames
                // hit the wire (and the journal) as they stream.
                cfg.buf_size = rng.range(8_192, 40_000) as usize;
                cfg.block_size = rng.range(30_000, 150_000);
                cfg.queue_capacity = rng.range(16_000, 200_000) as usize;
                cfg.hybrid_threshold = 150_000;
                cfg.journal_checkpoint_leaves = rng.range(1, 4);
            }
            let eng = EngineConfig {
                concurrency: rng.range(2, 4) as usize,
                parallel: rng.range(1, 3) as usize,
                hash_workers: rng.range(1, 3) as usize,
                batch_threshold: 50_000,
                batch_bytes: 120_000,
            };
            // Phase 1: kill at an arbitrary streamed-byte point (the trip
            // lands on the next frame boundary).
            let crash_at = rng.range(1, total.max(2));
            let faults = FaultPlan::none().with_crash_after_bytes(crash_at);
            let crashed = run_recoverable_local_transfer(
                &names,
                Arc::new(src.clone()),
                Arc::new(dst.clone()),
                &scfg,
                &rcfg,
                &eng,
                &faults,
            );
            if crashed.is_ok() {
                // The whole dataset fit before the crash boundary hit a
                // frame edge — already delivered; still a valid property
                // run (verify and move on).
                for (name, expect) in names.iter().zip(&contents) {
                    assert_eq!(&dst.get(name).unwrap(), expect, "seed {seed} {}", alg.name());
                }
                continue;
            }
            let err = format!("{:#}", crashed.unwrap_err());
            assert!(
                err.contains("injected crash") || err.contains("session"),
                "seed {seed} {}: unexpected failure mode: {err}",
                alg.name()
            );
            // What the handshake *must* negotiate, recomputed from the
            // journal files as they stand after the crash (phase 2
            // rewrites them, so snapshot now).
            let expected_skip = expected_common_watermarks(&jroot, 16_384);
            // Phase 2: restart against the journals.
            scfg.resume = true;
            rcfg.resume = true;
            let (report, rreports) = run_recoverable_local_transfer(
                &names,
                Arc::new(src.clone()),
                Arc::new(dst.clone()),
                &scfg,
                &rcfg,
                &eng,
                &FaultPlan::none(),
            )
            .unwrap_or_else(|e| {
                panic!("seed {seed} {}: resume failed: {e:#}", alg.name());
            });
            assert_eq!(rreports.len(), eng.concurrency);
            let totals = report.aggregate();
            // Bit-identical delivery.
            for (name, expect) in names.iter().zip(&contents) {
                let got = dst.get(name).unwrap_or_else(|| {
                    panic!("seed {seed} {}: missing {name} after resume", alg.name())
                });
                assert_eq!(
                    &got,
                    expect,
                    "seed {seed} {} c={} p={}: delivered bytes differ on {name}",
                    alg.name(),
                    eng.concurrency,
                    eng.parallel
                );
            }
            // Clean resume re-reads nothing: the prefix verifies by
            // folding journaled digests, never by re-reading bytes
            // (bound: one leaf per open file; here exactly zero).
            assert_eq!(
                totals.bytes_reread, 0,
                "seed {seed} {}: clean resume must not re-read",
                alg.name()
            );
            assert_eq!(totals.bytes_resent, 0, "seed {seed} {}", alg.name());
            // The resumed run sends exactly what the journals could not
            // prove delivered.
            assert_eq!(
                totals.bytes_sent + totals.bytes_skipped,
                total,
                "seed {seed} {}: skip accounting must partition the dataset",
                alg.name()
            );
            assert_eq!(
                totals.files as u64 + totals.files_skipped,
                n_files as u64,
                "seed {seed} {}",
                alg.name()
            );
            // Whatever the receiver journal attested (intersected with
            // the sender journal) must actually have been skipped.
            assert_eq!(
                totals.bytes_skipped, expected_skip,
                "seed {seed} {}: journal watermarks vs skipped bytes",
                alg.name()
            );
        }
    }
}

/// The byte savings the handshake must negotiate, recomputed from the two
/// journal directories exactly as `negotiate_sender`/`negotiate_receiver`
/// agree on them: per file, the shorter journal's complete-leaf prefix
/// (the full size only when both records are complete).
fn expected_common_watermarks(root: &TempDir, leaf: u64) -> u64 {
    let sj = Journal::open(&root.join("snd")).unwrap();
    let rj = Journal::open(&root.join("rcv")).unwrap();
    let srecs = sj.load_all().unwrap();
    let mut sum = 0u64;
    for (idx, rrec) in rj.load_all().unwrap() {
        let Some(srec) = srecs.get(&idx) else { continue };
        if srec.size != rrec.size || srec.leaf_size != leaf || rrec.leaf_size != leaf {
            continue;
        }
        if srec.is_complete() && rrec.is_complete() {
            sum += rrec.size;
        } else {
            sum += srec.aligned_leaves().min(rrec.aligned_leaves()) * leaf;
        }
    }
    sum
}

/// A bit-fault planted on the *tail* (beyond the crash point) strikes the
/// resumed stream; the journal-tree verification localizes and repairs it
/// at leaf granularity — `bytes_reread` stays within one leaf, honoring
/// the harness's repair bound even under tail corruption.
#[test]
fn resumed_tail_fault_repairs_at_leaf_granularity() {
    let mut rng = SplitMix64::new(0xD00D);
    let sizes = [200_000usize];
    let (src, names, contents) = mem_src(&sizes, &mut rng);
    let dst = MemStorage::new();
    let jroot = TempDir::create("fiver-crash-tail").expect("scratch dir");
    let (mut scfg, mut rcfg) = journaled_cfgs(RealAlgorithm::Fiver, &jroot, 16_384);
    for cfg in [&mut scfg, &mut rcfg] {
        cfg.buf_size = 16_384;
        cfg.journal_checkpoint_leaves = 1;
    }
    let eng = EngineConfig {
        concurrency: 2,
        parallel: 1,
        hash_workers: 2,
        batch_threshold: 0,
        batch_bytes: 1,
    };
    // Phase 1: crash halfway through the single file.
    let crashed = run_recoverable_local_transfer(
        &names,
        Arc::new(src.clone()),
        Arc::new(dst.clone()),
        &scfg,
        &rcfg,
        &eng,
        &FaultPlan::none().with_crash_after_bytes(100_000),
    );
    assert!(crashed.is_err(), "planned kill must abort the run");
    // Phase 2: resume with a first-attempt fault planted at byte 180_000
    // — journaled watermarks sit at/below ~114 KiB, so the fault strikes
    // the resumed tail stream.
    scfg.resume = true;
    rcfg.resume = true;
    let tail_fault = FaultPlan {
        faults: vec![Fault { file_idx: 0, offset: 180_000, bit: 2, occurrence: 0 }],
        crash: None,
    };
    let (report, _) = run_recoverable_local_transfer(
        &names,
        Arc::new(src.clone()),
        Arc::new(dst.clone()),
        &scfg,
        &rcfg,
        &eng,
        &tail_fault,
    )
    .expect("resumed run");
    let totals = report.aggregate();
    assert_eq!(&dst.get(&names[0]).unwrap(), &contents[0], "delivery must be bit-identical");
    assert!(totals.bytes_skipped > 0, "the journaled prefix must not re-send");
    assert_eq!(totals.failures_detected, 1, "tail corruption must be caught");
    assert!(
        totals.bytes_reread <= scfg.leaf_size,
        "tree repair localizes to one leaf, re-read {} > leaf {}",
        totals.bytes_reread,
        scfg.leaf_size
    );
    assert_eq!(totals.bytes_resent, totals.bytes_reread);
}

/// A tampered (divergent) receiver journal record must fail the prefix
/// root comparison at the handshake: the file falls back to a full
/// re-transfer and still lands bit-identical.
#[test]
fn resume_falls_back_on_journal_mismatch() {
    let mut rng = SplitMix64::new(0xBADC0DE);
    let sizes = [150_000usize];
    let (src, names, contents) = mem_src(&sizes, &mut rng);
    let dst = MemStorage::new();
    let jroot = TempDir::create("fiver-crash-tamper").expect("scratch dir");
    let (mut scfg, mut rcfg) = journaled_cfgs(RealAlgorithm::FiverMerkle, &jroot, 16_384);
    for cfg in [&mut scfg, &mut rcfg] {
        cfg.buf_size = 16_384;
        cfg.journal_checkpoint_leaves = 1;
    }
    let eng = EngineConfig {
        concurrency: 2,
        parallel: 2,
        hash_workers: 2,
        batch_threshold: 0,
        batch_bytes: 1,
    };
    let crashed = run_recoverable_local_transfer(
        &names,
        Arc::new(src.clone()),
        Arc::new(dst.clone()),
        &scfg,
        &rcfg,
        &eng,
        &FaultPlan::none().with_crash_after_bytes(80_000),
    );
    assert!(crashed.is_err(), "planned kill must abort the run");
    // Corrupt one digest byte in the receiver's journal record.
    let rec_path = Journal::open(&jroot.join("rcv")).unwrap().record_path(&names[0]);
    let mut bytes = std::fs::read(&rec_path).expect("receiver journal record exists");
    assert!(bytes.len() > 40, "record should hold at least one digest");
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    std::fs::write(&rec_path, &bytes).unwrap();
    // Resume: the handshake must reject the divergent prefix and fall
    // back to re-transfer — delivery still bit-identical, nothing skipped.
    scfg.resume = true;
    rcfg.resume = true;
    let (report, _) = run_recoverable_local_transfer(
        &names,
        Arc::new(src.clone()),
        Arc::new(dst.clone()),
        &scfg,
        &rcfg,
        &eng,
        &FaultPlan::none(),
    )
    .expect("resumed run");
    let totals = report.aggregate();
    assert_eq!(&dst.get(&names[0]).unwrap(), &contents[0]);
    assert_eq!(totals.bytes_skipped, 0, "a divergent journal must not skip anything");
    assert_eq!(totals.bytes_sent, 150_000, "full re-transfer after the rejected prefix");
    // The rejected record was discarded; the fresh run re-journaled it.
    let rj = Journal::open(&jroot.join("rcv")).unwrap();
    let rec = rj.find(&names[0]).unwrap().expect("record recreated by the fresh transfer");
    assert!(rec.is_complete());
}

/// The whole crash/resume cycle — kill at a frame boundary, journal
/// handshake, tail-only re-send, bit-identical delivery — must hold on
/// every storage I/O backend, with real files on both ends. This is the
/// durability-ordering proof per engine: the journaled watermark may
/// never attest bytes the backend's sync (`fdatasync` / `msync`) did not
/// actually persist, or the resumed prefix would diverge from storage
/// and the handshake's root comparison would reject it (costing the
/// skip) or — worse — deliver wrong bytes. Both algorithms that exercise
/// the two journaling paths run: FIVER (stream-side LeafTracker) and
/// FIVER-Merkle (journal folded into the verification tree job).
#[test]
fn crash_resume_across_storage_backends() {
    use fiver::storage::{read_all, FsStorage, IoBackend, Storage};
    for backend in IoBackend::ALL {
        for alg in [RealAlgorithm::Fiver, RealAlgorithm::FiverMerkle] {
            let mut rng = SplitMix64::new(0xBACC + backend as u64);
            let sizes = [120_000usize, 60_000, 90_000];
            let total: u64 = sizes.iter().map(|&s| s as u64).sum();
            let mut contents = Vec::new();
            let base = TempDir::create("fiver-crash-backend").expect("scratch dir");
            let src_fs = FsStorage::with_backend(&base.join("src"), backend).expect("src");
            let dst_fs = FsStorage::with_backend(&base.join("dst"), backend).expect("dst");
            let mut names = Vec::new();
            for (i, &size) in sizes.iter().enumerate() {
                let mut data = vec![0u8; size];
                rng.fork().fill_bytes(&mut data);
                let name = format!("k{i:03}");
                let mut w = src_fs.open_write(&name).expect("create source");
                w.write_next(&data).expect("write source");
                w.flush().expect("flush source");
                names.push(name);
                contents.push(data);
            }
            let src: Arc<dyn fiver::storage::Storage> = Arc::new(src_fs);
            let dst: Arc<dyn fiver::storage::Storage> = Arc::new(dst_fs);
            let (mut scfg, mut rcfg) = journaled_cfgs(alg, &base, 16_384);
            for cfg in [&mut scfg, &mut rcfg] {
                cfg.buf_size = 16_384;
                cfg.journal_checkpoint_leaves = 1;
                cfg.io_backend = backend;
            }
            let eng = EngineConfig {
                concurrency: 2,
                parallel: 2,
                hash_workers: 2,
                batch_threshold: 0,
                batch_bytes: 1,
            };
            // Phase 1: kill mid-dataset.
            let crashed = run_recoverable_local_transfer(
                &names,
                src.clone(),
                dst.clone(),
                &scfg,
                &rcfg,
                &eng,
                &FaultPlan::none().with_crash_after_bytes(total / 2),
            );
            assert!(
                crashed.is_err(),
                "{} {}: planned kill must abort the run",
                backend.name(),
                alg.name()
            );
            let expected_skip = expected_common_watermarks(&base, 16_384);
            // Phase 2: resume against the journals.
            scfg.resume = true;
            rcfg.resume = true;
            let (report, _) = run_recoverable_local_transfer(
                &names,
                src.clone(),
                dst.clone(),
                &scfg,
                &rcfg,
                &eng,
                &FaultPlan::none(),
            )
            .unwrap_or_else(|e| {
                panic!("{} {}: resume failed: {e:#}", backend.name(), alg.name())
            });
            let totals = report.aggregate();
            for (name, expect) in names.iter().zip(&contents) {
                let got = read_all(&dst, name).unwrap_or_else(|e| {
                    panic!("{} {}: read back {name}: {e:#}", backend.name(), alg.name())
                });
                assert_eq!(
                    &got,
                    expect,
                    "{} {}: delivered bytes differ on {name}",
                    backend.name(),
                    alg.name()
                );
            }
            assert_eq!(
                totals.bytes_reread,
                0,
                "{} {}: clean resume must not re-read",
                backend.name(),
                alg.name()
            );
            assert_eq!(
                totals.bytes_sent + totals.bytes_skipped,
                total,
                "{} {}: skip accounting must partition the dataset",
                backend.name(),
                alg.name()
            );
            assert_eq!(
                totals.bytes_skipped,
                expected_skip,
                "{} {}: journal watermarks vs skipped bytes (durability ordering)",
                backend.name(),
                alg.name()
            );
            // The report must attribute the run to the *effective* engine
            // (platforms without mmap/O_DIRECT degrade to buffered).
            assert_eq!(totals.io_backend, src.backend_name(), "reported backend must match");
        }
    }
}
