//! Integration: simulated paper experiments — the qualitative claims of
//! every figure hold in the reproduced testbeds (absolute numbers are
//! calibrated in config/mod.rs; these tests pin the *shape*: who wins,
//! roughly by how much, and where the crossovers are).

use fiver::config::{AlgoParams, Testbed, GB, MB};
use fiver::faults::FaultPlan;
use fiver::metrics::RunSummary;
use fiver::sim::algorithms::{checksum_only, run, transfer_only, Algorithm};
use fiver::workload::Dataset;

fn go(tb: Testbed, ds: &Dataset, alg: Algorithm) -> RunSummary {
    run(tb, AlgoParams::default(), ds, &FaultPlan::none(), alg)
}

/// Paper abstract: "FIVER is able to bring down the cost from 60% by the
/// state-of-the-art solutions to below 10%".
#[test]
fn headline_claim_fiver_under_10pct_sequential_near_60() {
    let tb = Testbed::esnet_lan();
    let ds = Dataset::uniform("10G", 10 * GB, 4);
    let fiver = go(tb, &ds, Algorithm::Fiver);
    let seq = go(tb, &ds, Algorithm::Sequential);
    let fo = fiver.overhead().unwrap();
    let so = seq.overhead().unwrap();
    assert!(fo < 0.10, "FIVER {fo}");
    assert!((0.40..0.90).contains(&so), "Sequential ~60%: {so}");
}

/// §III: "if checksum computation of a file takes 30 seconds and transfer
/// takes 10, FIVER finishes both in around 30 seconds".
#[test]
fn fiver_time_close_to_slower_leg() {
    for tb in [Testbed::esnet_lan(), Testbed::hpclab_40g(), Testbed::hpclab_1g()] {
        let ds = Dataset::uniform("4G", 4 * GB, 3);
        let s = go(tb, &ds, Algorithm::Fiver);
        let slower = s.t_checksum_only.max(s.t_transfer_only);
        assert!(
            s.total_time < slower * 1.12,
            "{}: FIVER {} vs slower leg {}",
            tb.name,
            s.total_time,
            slower
        );
    }
}

/// Fig 3a: in HPCLab-1G (checksum faster than network) block-level
/// pipelining imposes overhead similar to FIVER; file-level suffers on
/// single large files.
#[test]
fn fig3_block_similar_to_fiver_when_checksum_fast() {
    let tb = Testbed::hpclab_1g();
    let ds = Dataset::uniform("10G", 10 * GB, 1);
    let block = go(tb, &ds, Algorithm::BlockLevelPpl).overhead().unwrap();
    let fiver = go(tb, &ds, Algorithm::Fiver).overhead().unwrap();
    let file = go(tb, &ds, Algorithm::FileLevelPpl).overhead().unwrap();
    assert!((block - fiver).abs() < 0.08, "block {block} ~ fiver {fiver}");
    assert!(file > block + 0.10, "file {file} >> block {block}");
}

/// Fig 5b vs Fig 6b vs Fig 7b: Sorted-5M250M block-level overhead is large
/// everywhere the checksum is the bottleneck, and grows LAN -> WAN.
#[test]
fn sorted_block_overheads_by_testbed() {
    let ds = Dataset::sorted_5m250m(50);
    let b40 = go(Testbed::hpclab_40g(), &ds, Algorithm::BlockLevelPpl).overhead().unwrap();
    let lan = go(Testbed::esnet_lan(), &ds, Algorithm::BlockLevelPpl).overhead().unwrap();
    let wan = go(Testbed::esnet_wan(), &ds, Algorithm::BlockLevelPpl).overhead().unwrap();
    assert!(b40 > 0.35, "HPCLab-40G sorted (paper ~60%): {b40}");
    assert!(lan > 0.25, "ESNet-LAN sorted (paper 38%): {lan}");
    assert!(wan > lan, "WAN {wan} > LAN {lan} (paper 61% vs 38%)");
}

/// Fig 7a vs Fig 6a: WAN inflates overheads relative to LAN for the
/// pipelined baselines but FIVER stays under 10%.
#[test]
fn wan_amplifies_baselines_not_fiver() {
    let ds = Dataset::uniform("1G", GB, 10);
    let fiver_wan = go(Testbed::esnet_wan(), &ds, Algorithm::Fiver).overhead().unwrap();
    assert!(fiver_wan < 0.10, "FIVER WAN {fiver_wan}");
    let block_lan = go(Testbed::esnet_lan(), &ds, Algorithm::BlockLevelPpl).overhead().unwrap();
    let block_wan = go(Testbed::esnet_wan(), &ds, Algorithm::BlockLevelPpl).overhead().unwrap();
    assert!(block_wan >= block_lan, "WAN {block_wan} >= LAN {block_lan}");
}

/// Fig 8: average receiver hit ratios — FIVER/block ~100%, file-level and
/// sequential meaningfully lower on the ESNet mixed dataset.
#[test]
fn fig8_hit_ratio_averages() {
    let tb = Testbed::esnet_wan();
    let ds = Dataset::esnet_mixed(42);
    let fiver = go(tb, &ds, Algorithm::Fiver);
    let block = go(tb, &ds, Algorithm::BlockLevelPpl);
    let seq = go(tb, &ds, Algorithm::Sequential);
    assert!(fiver.dst_trace.average() > 0.995, "FIVER {}", fiver.dst_trace.average());
    assert!(block.dst_trace.average() > 0.97, "block {}", block.dst_trace.average());
    assert!(
        seq.dst_trace.average() < 0.93,
        "sequential should dip (paper 77.8%): {}",
        seq.dst_trace.average()
    );
    // FIVER finishes ahead of block-level (paper: 50 s earlier).
    assert!(fiver.total_time < block.total_time);
}

/// Fig 9: FIVER-Hybrid reduces execution time ~20% vs sequential while
/// matching its cache-miss volume (reliability equivalence).
#[test]
fn fig9_hybrid_tradeoff() {
    let tb = Testbed::esnet_wan();
    let ds = Dataset::esnet_mixed(42);
    let hybrid = go(tb, &ds, Algorithm::FiverHybrid);
    let seq = go(tb, &ds, Algorithm::Sequential);
    let speedup = 1.0 - hybrid.total_time / seq.total_time;
    assert!(
        (0.08..0.45).contains(&speedup),
        "paper ~20% reduction, got {:.1}%",
        speedup * 100.0
    );
    let miss_ratio =
        hybrid.dst_trace.total_misses() as f64 / seq.dst_trace.total_misses() as f64;
    assert!((0.5..1.5).contains(&miss_ratio), "cache-miss parity: {miss_ratio}");
}

/// Eq. 1 baselines are self-consistent: algorithm times are never faster
/// than the transfer-only baseline.
#[test]
fn baselines_bound_algorithms() {
    let tb = Testbed::hpclab_40g();
    let ds = Dataset::uniform("1G", GB, 5);
    let p = AlgoParams::default();
    let t_tx = transfer_only(tb, p, &ds);
    let t_ck = checksum_only(tb, p, &ds);
    assert!(t_tx > 0.0 && t_ck > 0.0);
    for alg in Algorithm::ALL {
        let s = run(tb, p, &ds, &FaultPlan::none(), alg);
        assert!(
            s.total_time >= t_tx * 0.999,
            "{}: {} < transfer-only {}",
            alg.name(),
            s.total_time,
            t_tx
        );
    }
}

/// Table III trend at the simulation level: execution time of FIVER
/// file-level verification grows steeply with faults; chunk-level barely.
#[test]
fn table3_trend() {
    let tb = Testbed::hpclab_40g();
    let ds = Dataset::table3_dataset();
    let p = AlgoParams::default();
    let base_file = run(tb, p, &ds, &FaultPlan::none(), Algorithm::Fiver).total_time;
    let f24 = FaultPlan::random(&ds, 24, 5);
    let file24 = run(tb, p, &ds, &f24, Algorithm::Fiver).total_time;
    let chunk24 = run(tb, p, &ds, &f24, Algorithm::FiverChunk).total_time;
    assert!(file24 / base_file > 1.30, "file 24-fault blowup {}", file24 / base_file);
    assert!(chunk24 / base_file < 1.25, "chunk 24-fault blowup {}", chunk24 / base_file);
}

/// TCP restarts: sequential accumulates slow-start restarts on large-file
/// datasets in the WAN (long checksum pauses exceed the RTO) while FIVER
/// keeps the pipe continuously busy.
#[test]
fn tcp_restart_accounting() {
    let tb = Testbed::esnet_wan();
    let ds = Dataset::uniform("10G", 10 * GB, 4);
    let seq = go(tb, &ds, Algorithm::Sequential);
    let fiver = go(tb, &ds, Algorithm::Fiver);
    assert!(seq.tcp_restarts >= 3, "sequential restarts {}", seq.tcp_restarts);
    assert_eq!(fiver.tcp_restarts, 0, "FIVER should never idle the pipe");
}

/// Mixed datasets preserve total bytes across algorithms (no silent loss
/// in the drivers).
#[test]
fn conservation_of_bytes() {
    let tb = Testbed::hpclab_1g();
    let ds = Dataset::mixed_shuffled("m", &[(10, 10 * MB), (3, 500 * MB)], 4);
    for alg in Algorithm::ALL {
        let s = go(tb, &ds, alg);
        assert!(s.total_time > 0.0, "{}", alg.name());
        assert_eq!(s.bytes_resent, 0, "{}: clean run resends nothing", alg.name());
    }
}
