//! End-to-end delta sync: deliver a dataset (populating journals), edit
//! a few leaves and rename one file at the source, then re-run with
//! `--delta` and require bit-identical delivery with only the dirty
//! leaf ranges on the wire. The name-keyed journal records are what make
//! the rename safe: every surviving file's basis is found under its own
//! name (an index-keyed scheme would shift every basis after the
//! rename), and the renamed file is re-journaled under its new name so
//! the *next* delta run matches it in place.

use std::sync::Arc;

use fiver::coordinator::journal::Journal;
use fiver::coordinator::scheduler::{EngineConfig, EngineReport};
use fiver::coordinator::session::run_recoverable_local_transfer;
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::HashAlgorithm;
use fiver::storage::{MemStorage, Storage};
use fiver::util::rng::SplitMix64;
use fiver::util::tmpdir::TempDir;

const LEAF: u64 = 16 * 1024;

/// Build an in-memory source with `files` pseudo-random files of `size`
/// bytes each.
fn mem_src(files: usize, size: usize, rng: &mut SplitMix64) -> (MemStorage, Vec<String>) {
    let storage = MemStorage::new();
    let mut names = Vec::new();
    for i in 0..files {
        let mut data = vec![0u8; size];
        rng.fork().fill_bytes(&mut data);
        let name = format!("e{i:03}");
        storage.put(&name, data);
        names.push(name);
    }
    (storage, names)
}

/// Journaled sender/receiver configs under `root` ("snd" / "rcv").
fn journaled_cfgs(root: &TempDir) -> (SessionConfig, SessionConfig) {
    let mut scfg =
        SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    scfg.leaf_size = LEAF;
    scfg.journal_dir = Some(root.join("snd"));
    let mut rcfg = scfg.clone();
    rcfg.journal_dir = Some(root.join("rcv"));
    (scfg, rcfg)
}

fn engine() -> EngineConfig {
    EngineConfig { concurrency: 2, parallel: 1, hash_workers: 2, batch_threshold: 0, batch_bytes: 1 }
}

fn run_once(
    names: &[String],
    src: &MemStorage,
    dst: &MemStorage,
    scfg: &SessionConfig,
    rcfg: &SessionConfig,
) -> EngineReport {
    let (report, _) = run_recoverable_local_transfer(
        names,
        Arc::new(src.clone()) as Arc<dyn Storage>,
        Arc::new(dst.clone()) as Arc<dyn Storage>,
        scfg,
        rcfg,
        &engine(),
        &FaultPlan::none(),
    )
    .expect("loopback engine run");
    report
}

fn assert_identical(names: &[String], src: &MemStorage, dst: &MemStorage) {
    for name in names {
        assert_eq!(
            src.get(name).expect("source file"),
            dst.get(name).expect("destination file"),
            "delivered bytes differ on {name}"
        );
    }
}

/// Flip one byte in `count` distinct leaves of each named file.
fn mutate_leaves(src: &MemStorage, names: &[String], count: u64, rng: &mut SplitMix64) {
    for name in names {
        let mut data = src.get(name).expect("source file");
        let leaves = (data.len() as u64 / LEAF).max(1);
        for k in 0..count {
            let l = (k * leaves / count.max(1)) % leaves; // distinct leaves
            let off = (l * LEAF) as usize + (rng.below(LEAF) as usize).min(data.len() - 1);
            data[off] ^= 0xA5;
        }
        src.put(name, data);
    }
}

/// Acceptance: ~5% of leaves mutated across every file plus one renamed
/// file => the `--delta` re-run delivers bit-identical data with under
/// 15% of the dataset on the wire, and a further unchanged re-run finds
/// the renamed file's basis under its new name (name-keyed records).
#[test]
fn delta_rerun_ships_only_dirty_leaves() {
    let files = 16usize;
    let size = 16 * LEAF as usize; // 16 leaves per file
    let total = (files * size) as u64;
    let mut rng = SplitMix64::new(0xD517A);
    let (src, mut names) = mem_src(files, size, &mut rng);
    let dst = MemStorage::new();
    let jroot = TempDir::create("fiver-delta-e2e").expect("scratch dir");
    let (mut scfg, mut rcfg) = journaled_cfgs(&jroot);

    // Run 1: full delivery (populates both journals).
    let first = run_once(&names, &src, &dst, &scfg, &rcfg).aggregate();
    assert_identical(&names, &src, &dst);
    assert!(first.bytes_sent >= total, "full run ships everything");

    // Mutate ~5% of each file's leaves (1 of 16) and rename one file.
    mutate_leaves(&src, &names, 1, &mut rng);
    src.rename(&names[0], "e999-renamed").expect("rename source file");
    names[0] = "e999-renamed".to_string();

    // Run 2: --delta. Only dirty leaves + the renamed file ship.
    scfg.delta = true;
    rcfg.delta = true;
    let second = run_once(&names, &src, &dst, &scfg, &rcfg).aggregate();
    assert_identical(&names, &src, &dst);
    assert!(
        second.bytes_sent < total * 15 / 100,
        "delta re-run sent {} of {} (>= 15%)",
        second.bytes_sent,
        total
    );
    assert!(second.bytes_skipped_delta > 0, "clean leaves must be matched in place");
    assert!(second.leaves_clean > second.leaves_dirty);
    // Every unrenamed file's run-1 journal record matches the receiver's
    // basis pair-for-pair, so the sender skips its rolling scan and ships
    // the mutated leaf as a literal off the cached path; the renamed file
    // has no sender record under its new name yet.
    assert_eq!(
        second.delta_scans_skipped,
        files as u64 - 1,
        "sender signature cache serves every unrenamed file"
    );
    assert_eq!(
        second.bytes_sent + second.bytes_skipped_delta,
        total,
        "every byte is either shipped or matched"
    );

    // The renamed file was re-journaled under its new name on both ends.
    for dir in ["snd", "rcv"] {
        let j = Journal::open(&jroot.join(dir)).expect("journal");
        let rec = j.find("e999-renamed").expect("journal read").expect("record for new name");
        assert_eq!(rec.size, size as u64, "{dir} journal records the renamed file");
        assert!(rec.is_complete());
    }

    // Run 3: nothing changed — the renamed file now deltas too, so the
    // wire carries no literals at all.
    let third = run_once(&names, &src, &dst, &scfg, &rcfg).aggregate();
    assert_identical(&names, &src, &dst);
    assert_eq!(third.bytes_sent, 0, "unchanged re-run ships nothing");
    assert_eq!(third.bytes_skipped_delta, total);
    assert_eq!(third.leaves_dirty, 0);
    // Run 2 re-journaled every file (renamed one included) on the sender,
    // so run 3 skips the rolling scan across the board.
    assert_eq!(third.delta_scans_skipped, files as u64);
}

/// A receiver without a journal still serves a delta basis by hashing
/// its existing data — slower, but the wire savings are identical.
#[test]
fn delta_works_without_receiver_journal() {
    let files = 6usize;
    let size = 8 * LEAF as usize;
    let total = (files * size) as u64;
    let mut rng = SplitMix64::new(0xD517B);
    let (src, names) = mem_src(files, size, &mut rng);
    let dst = MemStorage::new();
    let jroot = TempDir::create("fiver-delta-nojrnl").expect("scratch dir");
    let (mut scfg, _) = journaled_cfgs(&jroot);
    let mut rcfg = scfg.clone();
    rcfg.journal_dir = None; // cold receiver: basis hashed from storage

    run_once(&names, &src, &dst, &scfg, &rcfg);
    mutate_leaves(&src, &names, 1, &mut rng);
    scfg.delta = true;
    rcfg.delta = true;
    let rerun = run_once(&names, &src, &dst, &scfg, &rcfg).aggregate();
    assert_identical(&names, &src, &dst);
    assert!(
        rerun.bytes_sent < total / 2,
        "cold-basis delta sent {} of {}",
        rerun.bytes_sent,
        total
    );
    assert!(rerun.bytes_skipped_delta > 0);
}

/// Files the receiver has never seen (and sub-leaf files, which cannot
/// anchor a copy) fall back to a plain full send under `--delta`.
#[test]
fn delta_new_and_tiny_files_fall_back_to_full_copy() {
    let mut rng = SplitMix64::new(0xD517C);
    let (src, mut names) = mem_src(3, 4 * LEAF as usize, &mut rng);
    let dst = MemStorage::new();
    let jroot = TempDir::create("fiver-delta-new").expect("scratch dir");
    let (mut scfg, mut rcfg) = journaled_cfgs(&jroot);
    run_once(&names, &src, &dst, &scfg, &rcfg);

    // A brand-new file and a sub-leaf file join the dataset.
    let mut fresh = vec![0u8; 2 * LEAF as usize];
    rng.fill_bytes(&mut fresh);
    src.put("fresh", fresh);
    src.put("tiny", b"sub-leaf".to_vec());
    names.push("fresh".to_string());
    names.push("tiny".to_string());

    scfg.delta = true;
    rcfg.delta = true;
    let rerun = run_once(&names, &src, &dst, &scfg, &rcfg).aggregate();
    assert_identical(&names, &src, &dst);
    // The unchanged files match in place; the new + tiny files ship whole.
    assert_eq!(rerun.bytes_sent, 2 * LEAF + 8, "exactly the new bytes ship");
    assert_eq!(rerun.bytes_skipped_delta, 3 * 4 * LEAF);
}

/// Delta against a *stale* basis (the receiver's data changed after its
/// journal was written) must still deliver bit-identical data: the
/// journal-served signatures describe bytes that are gone, so matched
/// "clean" leaves would reconstruct garbage — the Merkle verification
/// backstop catches it and the repair path fixes every wrong leaf.
#[test]
fn delta_survives_stale_receiver_journal() {
    let files = 4usize;
    let size = 8 * LEAF as usize;
    let mut rng = SplitMix64::new(0xD517D);
    let (src, names) = mem_src(files, size, &mut rng);
    let dst = MemStorage::new();
    let jroot = TempDir::create("fiver-delta-stale").expect("scratch dir");
    let (mut scfg, mut rcfg) = journaled_cfgs(&jroot);
    run_once(&names, &src, &dst, &scfg, &rcfg);

    // Corrupt the receiver's copy of one file *behind the journal's
    // back*: the journal still vouches for the old bytes.
    let mut behind = dst.get(&names[1]).expect("dst file");
    for b in behind.iter_mut().take(LEAF as usize) {
        *b = !*b;
    }
    dst.put(&names[1], behind);

    scfg.delta = true;
    rcfg.delta = true;
    let rerun = run_once(&names, &src, &dst, &scfg, &rcfg).aggregate();
    assert_identical(&names, &src, &dst);
    assert!(
        rerun.failures_detected > 0,
        "the stale basis must trip verification, not slip through"
    );
}
