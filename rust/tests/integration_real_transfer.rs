//! Integration: real loopback transfers through the full coordinator
//! stack (sockets, threads, queue, verification, recovery) for every
//! algorithm, against both storage backends.

use std::sync::Arc;

use fiver::coordinator::session::run_local_transfer;
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::{hex_digest, HashAlgorithm};
use fiver::storage::{MemStorage, Storage};
use fiver::util::rng::SplitMix64;

/// Build an in-memory source with `sizes` pseudo-random files.
fn mem_src(sizes: &[usize], seed: u64) -> (MemStorage, Vec<String>, Vec<Vec<u8>>) {
    let storage = MemStorage::new();
    let mut rng = SplitMix64::new(seed);
    let mut names = Vec::new();
    let mut contents = Vec::new();
    for (i, &size) in sizes.iter().enumerate() {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        let name = format!("f{i:03}");
        storage.put(&name, data.clone());
        names.push(name);
        contents.push(data);
    }
    (storage, names, contents)
}

fn all_algorithms() -> Vec<RealAlgorithm> {
    RealAlgorithm::ALL
        .into_iter()
        .filter(|a| *a != RealAlgorithm::TransferOnly)
        .collect()
}

fn transfer_and_check(
    alg: RealAlgorithm,
    sizes: &[usize],
    faults: &FaultPlan,
    hash: HashAlgorithm,
) -> (fiver::coordinator::TransferReport, fiver::coordinator::receiver::ReceiverReport) {
    let (src, names, contents) = mem_src(sizes, 0xA11CE);
    let dst = MemStorage::new();
    let mut cfg = SessionConfig::new(alg, native_factory(hash));
    cfg.buf_size = 64 * 1024;
    cfg.block_size = 256 * 1024;
    cfg.queue_capacity = 512 * 1024;
    cfg.hybrid_threshold = 1 << 20; // files >= 1 MiB take the sequential path
    let (report, rreport) = run_local_transfer(
        &names,
        Arc::new(src),
        Arc::new(dst.clone()),
        &cfg,
        faults,
    )
    .unwrap_or_else(|e| panic!("{} transfer failed: {e:#}", alg.name()));
    // Ground truth: delivered bytes identical to source bytes.
    for (name, expect) in names.iter().zip(&contents) {
        let got = dst.get(name).unwrap_or_else(|| panic!("missing {name}"));
        assert_eq!(
            hex_digest(HashAlgorithm::Sha256, &got),
            hex_digest(HashAlgorithm::Sha256, expect),
            "{}: content mismatch on {name}",
            alg.name()
        );
    }
    (report, rreport)
}

#[test]
fn clean_transfer_all_algorithms() {
    let sizes = [300_000usize, 1_500_000, 70_000, 0, 999_999];
    for alg in all_algorithms() {
        let (report, rreport) =
            transfer_and_check(alg, &sizes, &FaultPlan::none(), HashAlgorithm::Fvr256);
        assert_eq!(report.files, sizes.len(), "{}", alg.name());
        assert_eq!(report.failures_detected, 0, "{}", alg.name());
        assert_eq!(report.bytes_resent, 0, "{}", alg.name());
        assert_eq!(rreport.files_received, sizes.len());
        assert!(rreport.units_verified > 0, "{}", alg.name());
    }
}

#[test]
fn transfer_only_skips_verification() {
    let sizes = [100_000usize, 50_000];
    let (report, rreport) = transfer_and_check(
        RealAlgorithm::TransferOnly,
        &sizes,
        &FaultPlan::none(),
        HashAlgorithm::Md5,
    );
    assert_eq!(report.failures_detected, 0);
    assert_eq!(rreport.units_verified, 0, "transfer-only must not verify");
}

#[test]
fn corruption_detected_and_repaired_every_algorithm() {
    let sizes = [400_000usize, 900_000, 250_000];
    // One fault in each file, mid-stream.
    let mut faults = FaultPlan::none();
    for (i, &s) in sizes.iter().enumerate() {
        faults.faults.push(fiver::faults::Fault {
            file_idx: i,
            offset: (s / 2) as u64,
            bit: 3,
            occurrence: 0,
        });
    }
    for alg in all_algorithms() {
        let (report, rreport) = transfer_and_check(alg, &sizes, &faults, HashAlgorithm::Fvr256);
        assert!(
            report.failures_detected >= sizes.len() as u64,
            "{}: detected {}",
            alg.name(),
            report.failures_detected
        );
        assert!(report.bytes_resent > 0, "{}", alg.name());
        assert_eq!(rreport.units_failed, report.failures_detected);
    }
}

#[test]
fn chunk_recovery_resends_less_than_file_recovery() {
    let sizes = [4_000_000usize];
    let faults = FaultPlan::at(0, 1_000_000, 5);
    let (file_rep, _) =
        transfer_and_check(RealAlgorithm::Fiver, &sizes, &faults, HashAlgorithm::Fvr256);
    let (chunk_rep, _) =
        transfer_and_check(RealAlgorithm::FiverChunk, &sizes, &faults, HashAlgorithm::Fvr256);
    assert_eq!(file_rep.bytes_resent, 4_000_000, "file-level resends everything");
    assert!(
        chunk_rep.bytes_resent <= 256 * 1024,
        "chunk-level resends one 256 KiB chunk, got {}",
        chunk_rep.bytes_resent
    );
}

#[test]
fn multiple_faults_in_one_file_converge() {
    let sizes = [2_000_000usize];
    let mut faults = FaultPlan::none();
    for k in 0..5 {
        faults.faults.push(fiver::faults::Fault {
            file_idx: 0,
            offset: 123_456 * (k as u64 + 1),
            bit: (k % 8) as u8,
            occurrence: 0,
        });
    }
    for alg in [
        RealAlgorithm::Fiver,
        RealAlgorithm::FiverChunk,
        RealAlgorithm::FiverMerkle,
        RealAlgorithm::Sequential,
    ] {
        let (report, _) = transfer_and_check(alg, &sizes, &faults, HashAlgorithm::Fvr256);
        assert!(report.failures_detected > 0, "{}", alg.name());
    }
}

/// Acceptance: with a fault plan corrupting k bytes of an N-byte file,
/// FIVER-Merkle's repair cost is O(k · leaf_size) — not O(N) — and the
/// destination digests match the source for every hash backend.
#[test]
fn merkle_repair_cost_is_leaf_local_for_all_hashes() {
    let n: usize = 8 << 20; // 8 MiB file
    let leaf: u64 = 64 << 10; // default 64 KiB leaves -> 128 leaves
    // k = 3 corrupted bytes, scattered into distinct leaves.
    let fault_offsets = [1_000_000u64, 3_500_000, 7_900_000];
    let mut faults = FaultPlan::none();
    for (k, &off) in fault_offsets.iter().enumerate() {
        faults.faults.push(fiver::faults::Fault {
            file_idx: 0,
            offset: off,
            bit: (k % 8) as u8,
            occurrence: 0,
        });
    }
    for hash in HashAlgorithm::ALL {
        let (report, rreport) =
            transfer_and_check(RealAlgorithm::FiverMerkle, &[n], &faults, hash);
        let k = fault_offsets.len() as u64;
        assert_eq!(report.failures_detected, 1, "{}: one root mismatch", hash.name());
        assert_eq!(report.repair_rounds, 1, "{}", hash.name());
        // O(k·leaf), with room for run coalescing — nowhere near O(N).
        assert!(
            report.bytes_resent + report.bytes_reread <= 4 * k * leaf,
            "{}: repair cost {} + {} not leaf-local",
            hash.name(),
            report.bytes_resent,
            report.bytes_reread
        );
        assert!(report.bytes_resent >= k * leaf - 2 * leaf, "{}", hash.name());
        assert_eq!(rreport.bytes_repaired, report.bytes_resent, "{}", hash.name());
        // Descent exchanges O(log n) node-range rounds, not O(n) digests:
        // root + ~log2(128) levels + fresh root.
        assert!(
            (2u64..=12).contains(&report.verify_rtts),
            "{}: verify_rtts {}",
            hash.name(),
            report.verify_rtts
        );
    }
}

/// A clean FIVER-Merkle session costs exactly one root exchange per file
/// and no repair traffic.
#[test]
fn merkle_clean_run_is_one_rtt_per_file() {
    let sizes = [300_000usize, 0, 1_234_567];
    let (report, rreport) = transfer_and_check(
        RealAlgorithm::FiverMerkle,
        &sizes,
        &FaultPlan::none(),
        HashAlgorithm::Fvr256,
    );
    assert_eq!(report.failures_detected, 0);
    assert_eq!(report.bytes_resent, 0);
    assert_eq!(report.bytes_reread, 0);
    assert_eq!(report.repair_rounds, 0);
    assert_eq!(report.verify_rtts, sizes.len() as u64);
    assert_eq!(rreport.units_verified, sizes.len() as u64);
}

#[test]
fn works_with_every_hash_algorithm() {
    let sizes = [200_000usize, 123_457];
    for hash in HashAlgorithm::ALL {
        let (report, _) =
            transfer_and_check(RealAlgorithm::Fiver, &sizes, &FaultPlan::none(), hash);
        assert_eq!(report.failures_detected, 0, "{}", hash.name());
    }
}

#[test]
fn fs_storage_end_to_end() {
    use fiver::storage::FsStorage;
    use fiver::util::tmpdir::TempDir;
    use fiver::workload::Dataset;
    let base = TempDir::create("fiver-it-fs").unwrap();
    let ds = Dataset::uniform("it", 3 << 20, 4);
    ds.materialize(&base.join("src"), 11).unwrap();
    let names: Vec<String> = ds.files.iter().map(|f| f.name.clone()).collect();
    let src: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("src")).unwrap());
    let dst: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("dst")).unwrap());
    let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    let (report, rreport) = run_local_transfer(&names, src, dst, &cfg, &FaultPlan::none()).unwrap();
    assert_eq!(report.files, 4);
    assert_eq!(rreport.units_failed, 0);
    for f in &ds.files {
        let a = std::fs::read(base.join("src").join(&f.name)).unwrap();
        let b = std::fs::read(base.join("dst").join(&f.name)).unwrap();
        assert_eq!(a, b, "{}", f.name);
    }
}

/// The engine over real files: concurrency + striping against FsStorage
/// in a unique scratch dir (safe under default test parallelism).
#[test]
fn fs_storage_engine_end_to_end() {
    use fiver::coordinator::scheduler::EngineConfig;
    use fiver::coordinator::session::run_parallel_local_transfer;
    use fiver::storage::FsStorage;
    use fiver::util::tmpdir::TempDir;
    use fiver::workload::Dataset;
    let base = TempDir::create("fiver-it-fse").unwrap();
    let ds = Dataset::uniform("ite", 1 << 20, 9);
    ds.materialize(&base.join("src"), 13).unwrap();
    let names: Vec<String> = ds.files.iter().map(|f| f.name.clone()).collect();
    let src: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("src")).unwrap());
    let dst: Arc<dyn Storage> = Arc::new(FsStorage::new(&base.join("dst")).unwrap());
    let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    let eng = EngineConfig {
        concurrency: 3,
        parallel: 2,
        hash_workers: 3,
        batch_threshold: 0,
        batch_bytes: 1,
    };
    let (report, rreports) =
        run_parallel_local_transfer(&names, src, dst, &cfg, &eng, &FaultPlan::none()).unwrap();
    let total = report.aggregate();
    assert_eq!(total.files, 9);
    assert_eq!(total.bytes_sent, 9 << 20);
    assert_eq!(rreports.iter().map(|r| r.files_received).sum::<usize>(), 9);
    for f in &ds.files {
        let a = std::fs::read(base.join("src").join(&f.name)).unwrap();
        let b = std::fs::read(base.join("dst").join(&f.name)).unwrap();
        assert_eq!(a, b, "{}", f.name);
    }
}

#[test]
fn hybrid_mixes_paths_by_size() {
    // Small files (queue path) + one large file (sequential path) in one
    // session.
    let sizes = [100_000usize, 5_000_000, 80_000];
    let (report, rreport) = transfer_and_check(
        RealAlgorithm::FiverHybrid,
        &sizes,
        &FaultPlan::none(),
        HashAlgorithm::Fvr256,
    );
    assert_eq!(report.files, 3);
    assert_eq!(rreport.units_verified, 3);
}

#[test]
fn large_single_stream_through_small_queue() {
    // Queue capacity (512 KiB) far below file size: back-pressure path.
    let sizes = [6_000_000usize];
    let (report, _) =
        transfer_and_check(RealAlgorithm::Fiver, &sizes, &FaultPlan::none(), HashAlgorithm::Sha256);
    assert_eq!(report.bytes_sent, 6_000_000);
}
