//! Property tests over the substrate modules: page cache, fluid engine,
//! TCP model, hashes, JSON parser (in-tree seeded generators — no proptest
//! crate offline).

use fiver::cache::PageCache;
use fiver::hashes::{hex_digest, HashAlgorithm};
use fiver::net::{TcpConn, TcpParams};
use fiver::sim::FluidSim;
use fiver::util::json::Json;
use fiver::util::rng::SplitMix64;

/// PROPERTY: cache accounting — hits + misses == bytes requested; hit
/// ratio in [0,1]; used() never exceeds capacity.
#[test]
fn prop_cache_accounting() {
    for seed in 0..30u64 {
        let mut rng = SplitMix64::new(seed + 1);
        let capacity = rng.range(0, 64) * (1 << 20);
        let mut c = PageCache::new(capacity);
        let mut requested = 0u64;
        for _ in 0..rng.range(5, 60) {
            let file = rng.below(6);
            let offset = rng.below(32 << 20);
            let len = rng.range(1, 8 << 20);
            if rng.below(2) == 0 {
                let acc = c.read(file, offset, len);
                assert_eq!(acc.total(), len, "seed {seed}");
                requested += len;
            } else {
                c.write(file, offset, len);
            }
            assert!(c.used() <= capacity.max(1 << 20), "seed {seed}: used > capacity");
        }
        assert_eq!(c.total_hits + c.total_misses, requested, "seed {seed}");
        let r = c.hit_ratio();
        assert!((0.0..=1.0).contains(&r), "seed {seed}: {r}");
    }
}

/// PROPERTY: immediately re-reading a just-read range of a small file is
/// all hits (temporal locality), for any file that fits in capacity.
#[test]
fn prop_cache_reread_hits() {
    for seed in 0..20u64 {
        let mut rng = SplitMix64::new(seed + 77);
        let mut c = PageCache::new(256 << 20);
        let len = rng.range(1, 100 << 20);
        c.read(1, 0, len);
        let acc = c.read(1, 0, len);
        assert_eq!(acc.hit_bytes, len, "seed {seed} len {len}");
    }
}

/// PROPERTY: fluid engine conserves work — total bytes moved equals the
/// sum of flow sizes, and completion times are consistent with capacity
/// (never faster than bytes/capacity on a shared resource).
#[test]
fn prop_fluid_conservation() {
    for seed in 0..25u64 {
        let mut rng = SplitMix64::new(seed + 3);
        let mut sim = FluidSim::new();
        let capacity = rng.range(10, 10_000) as f64;
        let r = sim.add_resource("r", capacity);
        let n = rng.range(1, 6) as usize;
        let mut total = 0.0;
        let mut flows = Vec::new();
        for _ in 0..n {
            let bytes = rng.range(100, 100_000) as f64;
            total += bytes;
            flows.push(sim.start_flow(bytes, vec![(r, 1.0)], None));
        }
        let mut t_end = 0.0;
        for f in &flows {
            t_end = sim.run_until_done(*f).max(t_end);
        }
        let lower_bound = total / capacity;
        assert!(
            t_end >= lower_bound * 0.999,
            "seed {seed}: finished {t_end} < bound {lower_bound}"
        );
        // With identical demands the resource is never idle: equality.
        assert!(
            t_end <= lower_bound * 1.001,
            "seed {seed}: work-conserving bound violated: {t_end} vs {lower_bound}"
        );
    }
}

/// PROPERTY: max-min fairness — equal flows on one resource get equal
/// rates; a capped flow never exceeds its cap; total allocation never
/// exceeds capacity.
#[test]
fn prop_fluid_fairness_and_caps() {
    for seed in 0..25u64 {
        let mut rng = SplitMix64::new(seed + 5);
        let mut sim = FluidSim::new();
        let capacity = rng.range(100, 10_000) as f64;
        let r = sim.add_resource("r", capacity);
        let n = rng.range(2, 6) as usize;
        let mut flows = Vec::new();
        let mut caps = Vec::new();
        for _ in 0..n {
            let cap = if rng.below(2) == 0 {
                Some(rng.range(1, capacity as u64) as f64)
            } else {
                None
            };
            caps.push(cap);
            flows.push(sim.start_flow(1e12, vec![(r, 1.0)], cap));
        }
        sim.recompute_rates();
        let rates: Vec<f64> = flows.iter().map(|&f| sim.rate(f)).collect();
        let total: f64 = rates.iter().sum();
        assert!(total <= capacity * 1.001, "seed {seed}: over-allocated {total}");
        for (i, cap) in caps.iter().enumerate() {
            if let Some(c) = cap {
                assert!(rates[i] <= c * 1.001, "seed {seed}: cap violated");
            }
        }
        // Uncapped flows all get the same (maximal) rate.
        let uncapped: Vec<f64> = rates
            .iter()
            .zip(&caps)
            .filter(|(_, c)| c.is_none())
            .map(|(r, _)| *r)
            .collect();
        for w in uncapped.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-6, "seed {seed}: unfair {w:?}");
        }
    }
}

/// PROPERTY: TCP model — cwnd is monotone during uninterrupted activity,
/// rate never exceeds bandwidth, and transfer_time is monotone in bytes.
#[test]
fn prop_tcp_monotonicity() {
    for seed in 0..25u64 {
        let mut rng = SplitMix64::new(seed + 9);
        let bw = rng.range(1_000_000, 12_500_000_000) as f64;
        let rtt = rng.range(1, 200) as f64 / 1000.0;
        let p = TcpParams::new(bw, rtt);
        let mut conn = TcpConn::new(p);
        conn.on_active(0.0);
        let mut last = conn.cwnd();
        let mut t = 0.0;
        for _ in 0..50 {
            let dt = rng.range(1, 1000) as f64 / 1000.0;
            conn.advance(t, t + dt);
            t += dt;
            assert!(conn.cwnd() >= last * 0.999, "seed {seed}: cwnd shrank while active");
            assert!(conn.rate() <= bw * 1.001, "seed {seed}: rate above bandwidth");
            last = conn.cwnd();
        }
        let b1 = rng.range(1, 1 << 20);
        let b2 = b1 + rng.range(1, 1 << 24);
        let t1 = TcpConn::new(p).transfer_time(0.0, b1);
        let t2 = TcpConn::new(p).transfer_time(0.0, b2);
        assert!(t2 >= t1, "seed {seed}: transfer_time not monotone");
    }
}

/// PROPERTY: all hash implementations are split-invariant (streaming
/// equals one-shot) on random data and random split points.
#[test]
fn prop_hash_split_invariance() {
    for seed in 0..15u64 {
        let mut rng = SplitMix64::new(seed + 21);
        let mut data = vec![0u8; rng.range(0, 10_000) as usize];
        rng.fill_bytes(&mut data);
        for alg in HashAlgorithm::ALL {
            let oneshot = hex_digest(alg, &data);
            let mut h = alg.hasher();
            let mut pos = 0;
            while pos < data.len() {
                let n = (rng.range(1, 777) as usize).min(data.len() - pos);
                h.update(&data[pos..pos + n]);
                pos += n;
            }
            assert_eq!(
                fiver::util::hex::encode(&h.finalize()),
                oneshot,
                "seed {seed} {}",
                alg.name()
            );
        }
    }
}

/// PROPERTY: distinct random inputs give distinct digests (no trivial
/// collisions across a few hundred samples).
#[test]
fn prop_hash_distinctness() {
    let mut seen = std::collections::HashSet::new();
    let mut rng = SplitMix64::new(0xD15);
    for _ in 0..300 {
        let mut data = vec![0u8; rng.range(1, 500) as usize];
        rng.fill_bytes(&mut data);
        for alg in HashAlgorithm::ALL {
            seen.insert(hex_digest(alg, &data));
        }
    }
    assert_eq!(seen.len(), 300 * 4, "digest collision detected");
}

/// PROPERTY: the JSON parser accepts every value it can print (round-trip
/// through a simple serializer) for randomly generated documents.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut SplitMix64, depth: u32) -> (String, Json) {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => ("null".into(), Json::Null),
            1 => ("true".into(), Json::Bool(true)),
            2 => {
                let n = rng.below(1_000_000) as f64;
                (format!("{n}"), Json::Num(n))
            }
            3 => {
                let s: String = (0..rng.below(12))
                    .map(|_| char::from(b'a' + (rng.below(26) as u8)))
                    .collect();
                (format!("\"{s}\""), Json::Str(s))
            }
            4 => {
                let n = rng.below(4) as usize;
                let items: Vec<(String, Json)> = (0..n).map(|_| gen(rng, depth - 1)).collect();
                let text = format!(
                    "[{}]",
                    items.iter().map(|(t, _)| t.clone()).collect::<Vec<_>>().join(",")
                );
                (text, Json::Arr(items.into_iter().map(|(_, v)| v).collect()))
            }
            _ => {
                let n = rng.below(4) as usize;
                let mut map = std::collections::BTreeMap::new();
                let mut parts = Vec::new();
                for i in 0..n {
                    let (t, v) = gen(rng, depth - 1);
                    let key = format!("k{i}");
                    parts.push(format!("\"{key}\":{t}"));
                    map.insert(key, v);
                }
                (format!("{{{}}}", parts.join(",")), Json::Obj(map))
            }
        }
    }
    for seed in 0..40u64 {
        let mut rng = SplitMix64::new(seed + 31);
        let (text, expect) = gen(&mut rng, 3);
        let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {text}: {e}"));
        assert_eq!(parsed, expect, "seed {seed}: {text}");
    }
}

/// PROPERTY: SplitMix64 sub-streams (fork) are independent enough that
/// identical parents produce identical children, distinct parents distinct
/// children.
#[test]
fn prop_rng_fork_determinism() {
    for seed in 0..10u64 {
        let mut a = SplitMix64::new(seed);
        let mut b = SplitMix64::new(seed);
        assert_eq!(a.fork().next_u64(), b.fork().next_u64());
        let mut c = SplitMix64::new(seed + 1);
        assert_ne!(a.next_u64(), c.next_u64());
    }
}
