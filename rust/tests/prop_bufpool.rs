//! Property tests over the zero-copy data plane's invariants:
//! `SharedBuf` slicing/aliasing, `BufferPool` return-on-last-drop and
//! exhaustion backpressure, and `ByteQueue` byte accounting with sliced
//! refcounted buffers (in-tree seeded generators — no proptest crate
//! offline; see rust/src/util/rng.rs).

use std::time::Duration;

use fiver::coordinator::bufpool::{BufferPool, SharedBuf};
use fiver::coordinator::queue::ByteQueue;
use fiver::util::rng::SplitMix64;

/// PROPERTY: arbitrary slice trees over one backing always read the same
/// bytes as the equivalent Vec slices, never alias outside their range,
/// and keep the backing alive until the last view drops.
#[test]
fn prop_slices_match_vec_semantics() {
    for seed in 0..30u64 {
        let mut rng = SplitMix64::new(seed + 0xB0F);
        let len = rng.range(1, 4096) as usize;
        let mut data = vec![0u8; len];
        rng.fill_bytes(&mut data);
        let pool = BufferPool::new(len, 1);
        let mut buf = pool.get();
        buf[..len].copy_from_slice(&data);
        let root = buf.freeze(len);
        // Random nested slices.
        let mut views: Vec<(usize, usize, SharedBuf)> = vec![(0, len, root.clone())];
        drop(root);
        for _ in 0..rng.range(1, 16) {
            let (base_off, base_len, view) = {
                let pick = &views[rng.below(views.len() as u64) as usize];
                (pick.0, pick.1, pick.2.clone())
            };
            let start = rng.below(base_len as u64 + 1) as usize;
            let end = start + rng.below((base_len - start) as u64 + 1) as usize;
            let sub = view.slice(start, end);
            assert_eq!(
                &sub[..],
                &data[base_off + start..base_off + end],
                "seed {seed}: slice [{start},{end}) of view at +{base_off}"
            );
            views.push((base_off + start, end - start, sub));
        }
        // The single backing is still lent out while any view lives.
        assert!(pool.try_get().is_none(), "seed {seed}: backing must stay lent");
        drop(views);
        assert_eq!(pool.free_buffers(), 1, "seed {seed}: last drop returns the backing");
        assert_eq!(pool.allocated(), 1, "seed {seed}: exactly one backing ever allocated");
    }
}

/// PROPERTY: dropping N references (clones + slices) in any order returns
/// the buffer exactly once, after the final drop.
#[test]
fn prop_return_on_last_drop_any_order() {
    for seed in 0..30u64 {
        let mut rng = SplitMix64::new(seed + 0xD00D);
        let pool = BufferPool::new(32, 1);
        let root = pool.get().freeze(32);
        let mut refs: Vec<SharedBuf> = vec![root];
        for _ in 0..rng.range(1, 10) {
            let src = refs[rng.below(refs.len() as u64) as usize].clone();
            let view = if rng.below(2) == 0 {
                let mid = rng.below(src.len() as u64 + 1) as usize;
                src.slice(0, mid)
            } else {
                src
            };
            refs.push(view);
        }
        // Shuffle-drop.
        while !refs.is_empty() {
            let i = rng.below(refs.len() as u64) as usize;
            refs.swap_remove(i);
            if refs.is_empty() {
                break;
            }
            assert_eq!(pool.free_buffers(), 0, "seed {seed}: early return with live refs");
        }
        assert_eq!(pool.free_buffers(), 1, "seed {seed}");
    }
}

/// PROPERTY: an exhausted pool blocks `get` until a buffer returns, and
/// `get_or_alloc` degrades to a counted unpooled allocation instead of
/// blocking forever.
#[test]
fn prop_exhaustion_backpressure() {
    let pool = BufferPool::new(64, 2);
    let a = pool.get().freeze(64);
    let b = pool.get().freeze(64);
    assert!(pool.try_get().is_none());

    // Blocking get parks until a return. The waiter hands its PoolBuf
    // back to this thread so the pool stays exhausted for the fallback
    // assertions below.
    let pool2 = pool.clone();
    let waiter = std::thread::spawn(move || {
        let start = std::time::Instant::now();
        let got = pool2.get();
        (start.elapsed(), got)
    });
    std::thread::sleep(Duration::from_millis(60));
    drop(a);
    let (waited, got) = waiter.join().unwrap();
    assert!(got.is_pooled());
    assert!(waited >= Duration::from_millis(40), "get must block on exhaustion: {waited:?}");

    // get_or_alloc gives up after the grace period (b + got still held).
    let fallback = pool.get_or_alloc(Duration::from_millis(10));
    assert!(!fallback.is_pooled());
    assert_eq!(pool.fallback_allocs(), 1);
    drop(b);
    assert!(pool.get_or_alloc(Duration::from_millis(10)).is_pooled());
    assert_eq!(pool.fallback_allocs(), 1, "grace-period success is not a fallback");
    drop(got);
}

/// PROPERTY: deliberate pool exhaustion increments `fallback_allocs`
/// once per starved acquisition, `peak_in_flight` records the high-water
/// mark, and the pool *recovers* — once the held refcounts drop, an
/// arbitrary number of steady-state cycles takes pooled buffers without
/// a single further fallback.
#[test]
fn prop_starvation_counts_fallbacks_then_recovers() {
    for seed in 0..10u64 {
        let mut rng = SplitMix64::new(seed + 0x57A8);
        let cap = rng.range(2, 6) as usize;
        let pool = BufferPool::new(128, cap);
        // Exhaust: hold every pooled buffer via frozen refcounts.
        let held: Vec<SharedBuf> = (0..cap).map(|_| pool.get().freeze(128)).collect();
        assert_eq!(pool.in_flight(), cap);
        assert_eq!(pool.peak_in_flight(), cap);
        // Starved acquisitions fall back and are counted, one each.
        let n_fallback = rng.range(1, 5);
        let fallbacks: Vec<_> =
            (0..n_fallback).map(|_| pool.get_or_alloc(Duration::from_millis(5))).collect();
        assert!(fallbacks.iter().all(|b| !b.is_pooled()));
        assert_eq!(pool.fallback_allocs(), n_fallback);
        assert_eq!(pool.in_flight(), cap, "fallbacks never count as pooled in-flight");
        // Recovery: refcounts drop, buffers return, and steady-state
        // cycles stay fallback-free from then on.
        drop(held);
        drop(fallbacks);
        assert_eq!(pool.free_buffers(), cap);
        assert_eq!(pool.in_flight(), 0);
        for _ in 0..rng.range(8, 40) {
            let take = rng.range(1, cap as u64) as usize;
            let round: Vec<SharedBuf> = (0..take)
                .map(|_| pool.get_or_alloc(Duration::from_millis(50)).freeze(64))
                .collect();
            assert!(round.iter().all(|b| b.len() == 64));
            drop(round);
        }
        assert_eq!(
            pool.fallback_allocs(),
            n_fallback,
            "seed {seed}: zero-fallback steady state after recovery"
        );
        assert_eq!(pool.peak_in_flight(), cap);
        assert_eq!(pool.allocated(), cap, "recovered cycles recycle, never re-allocate");
    }
}

/// PROPERTY: ByteQueue byte accounting is exact for arbitrary slice
/// patterns — `len_bytes` equals queued view lengths (not backing sizes),
/// `try_add` hands the exact buffer back on a full queue, and spilled
/// buffers round-trip through a retry without loss or reorder.
#[test]
fn prop_queue_accounting_with_slices() {
    for seed in 0..25u64 {
        let mut rng = SplitMix64::new(seed + 0xACC);
        let cap = rng.range(512, 8192) as usize;
        let q = ByteQueue::new(cap);
        let backing_len = rng.range(1024, 16 * 1024) as usize;
        let mut data = vec![0u8; backing_len];
        rng.fill_bytes(&mut data);
        let backing = SharedBuf::from_vec(data.clone());

        // Cut the backing into consecutive slices (the sender/receiver
        // pattern: one big read shared as per-unit views).
        let mut cuts: Vec<(usize, usize)> = Vec::new();
        let mut pos = 0usize;
        while pos < backing_len {
            let n = (rng.range(1, 2048) as usize).min(backing_len - pos);
            cuts.push((pos, pos + n));
            pos += n;
        }

        let mut queued_bytes = 0usize;
        let mut spill: std::collections::VecDeque<SharedBuf> = Default::default();
        let mut consumed: Vec<u8> = Vec::new();
        for &(s, e) in &cuts {
            let view = backing.slice(s, e);
            let went_in = if spill.is_empty() {
                match q.try_add(view) {
                    Ok(()) => true,
                    Err(back) => {
                        assert_eq!(back, data[s..e].to_vec(), "seed {seed}: exact buffer back");
                        spill.push_back(back);
                        false
                    }
                }
            } else {
                spill.push_back(view);
                false
            };
            if went_in {
                queued_bytes += e - s;
            }
            assert_eq!(q.len_bytes(), queued_bytes, "seed {seed}: accounting after add");
            // Occasionally drain one buffer and retry the spill (the
            // merger's pump_spill).
            if rng.below(3) == 0 {
                if let Some(buf) = (queued_bytes > 0).then(|| q.remove().unwrap()) {
                    queued_bytes -= buf.len();
                    consumed.extend_from_slice(&buf);
                }
                while let Some(front) = spill.pop_front() {
                    let n = front.len();
                    match q.try_add(front) {
                        Ok(()) => queued_bytes += n,
                        Err(back) => {
                            spill.push_front(back);
                            break;
                        }
                    }
                }
                assert_eq!(q.len_bytes(), queued_bytes, "seed {seed}: accounting after pump");
            }
        }
        // Final drain: spill first (blocking add is fine here — the
        // consumer below is this thread), then the queue.
        for buf in spill.drain(..) {
            // Make room, then add.
            while q.len_bytes() > 0 && q.len_bytes() + buf.len() > cap {
                let b = q.remove().unwrap();
                consumed.extend_from_slice(&b);
            }
            assert!(q.add(buf));
        }
        q.close();
        while let Some(b) = q.remove() {
            consumed.extend_from_slice(&b);
        }
        assert_eq!(consumed.len(), backing_len, "seed {seed}: no loss");
        assert_eq!(consumed, data, "seed {seed}: order preserved");
    }
}

/// PROPERTY: pooled buffers cycled through a queue by a consumer thread
/// reach a steady state bounded by the pool capacity — the pool never
/// grows past its cap and never takes a fallback allocation when sized to
/// cover the queue.
#[test]
fn prop_pool_steady_state_through_queue() {
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(seed + 0x57EAD);
        let buf_size = rng.range(256, 2048) as usize;
        let queue_cap = buf_size * rng.range(2, 6) as usize;
        // Enough buffers for a full queue plus one in flight on each side.
        let pool = BufferPool::new(buf_size, queue_cap / buf_size + 2);
        let q = ByteQueue::new(queue_cap);
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            let mut total = 0usize;
            while let Some(b) = q2.remove() {
                total += b.len();
            }
            total
        });
        let rounds = 200usize;
        for i in 0..rounds {
            let mut b = pool.get();
            b[0] = i as u8;
            assert!(q.add(b.freeze(buf_size)));
        }
        q.close();
        let total = consumer.join().unwrap();
        assert_eq!(total, rounds * buf_size, "seed {seed}");
        assert!(
            pool.allocated() <= pool.capacity(),
            "seed {seed}: pool grew past its cap ({} > {})",
            pool.allocated(),
            pool.capacity()
        );
        assert_eq!(pool.fallback_allocs(), 0, "seed {seed}: steady state must not fall back");
        assert_eq!(pool.free_buffers(), pool.allocated(), "seed {seed}: all returned");
    }
}

/// PROPERTY: the adaptive sizer driven to its ceiling from random (often
/// odd) starting capacities always clamps `capacity <= max_capacity`,
/// grows by half-steps of `(capacity / 2).max(1)`, and resets its miss
/// counter on every grow — so each grow costs exactly
/// `GROW_FALLBACK_THRESHOLD` fallback allocations, never fewer.
#[test]
fn prop_growth_to_ceiling_clamps_odd_capacities() {
    use fiver::coordinator::bufpool::GROW_FALLBACK_THRESHOLD;
    for seed in 0..12u64 {
        let mut rng = SplitMix64::new(seed * 31 + 0x60DD);
        let cap0 = rng.range(1, 8) as usize;
        let pool = BufferPool::with_options(32, cap0, 1, cap0 + rng.range(0, 9) as usize);
        assert_eq!(pool.capacity(), cap0, "seed {seed}");
        let max = pool.max_capacity();
        let mut held: Vec<_> = (0..cap0).map(|_| pool.get()).collect();
        let mut expect_cap = cap0;
        let mut expect_grows = 0u64;
        while pool.capacity() < max {
            // The miss counter starts at zero (construction / the last
            // grow reset it): exactly GROW_FALLBACK_THRESHOLD misses
            // fall back before the sizer reacts.
            for m in 0..GROW_FALLBACK_THRESHOLD {
                let b = pool.get_or_alloc(Duration::from_millis(1));
                assert!(!b.is_pooled(), "seed {seed}: miss {m} must fall back");
                assert_eq!(pool.grow_events(), expect_grows, "seed {seed}: premature grow");
                assert_eq!(pool.capacity(), expect_cap, "seed {seed}");
            }
            // ...then the next exhausted call grows by the half-step,
            // clamped to the ceiling, and serves a pooled buffer.
            let grown = pool.get_or_alloc(Duration::from_millis(1));
            assert!(grown.is_pooled(), "seed {seed}: sustained exhaustion must grow");
            expect_cap = (expect_cap + (expect_cap / 2).max(1)).min(max);
            expect_grows += 1;
            assert_eq!(pool.capacity(), expect_cap, "seed {seed}");
            assert!(pool.capacity() <= pool.max_capacity(), "seed {seed}: ceiling breached");
            assert_eq!(pool.grow_events(), expect_grows, "seed {seed}");
            held.push(grown);
            // Occupy the fresh headroom so the next round starts exhausted.
            while pool.allocated() < pool.capacity() {
                held.push(pool.get());
            }
        }
        // At the ceiling, exhaustion can only fall back — capacity and
        // the grow count never move again.
        for _ in 0..2 * GROW_FALLBACK_THRESHOLD {
            assert!(!pool.get_or_alloc(Duration::from_millis(1)).is_pooled(), "seed {seed}");
            assert_eq!(pool.capacity(), max, "seed {seed}: capacity moved at the cap");
        }
        assert_eq!(pool.grow_events(), expect_grows, "seed {seed}");
        drop(held);
        assert_eq!(pool.in_flight(), 0, "seed {seed}: every pooled buffer returned");
    }
}
