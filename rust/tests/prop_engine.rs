//! Property tests over the parallel engine: N concurrent work-stealing
//! sessions × P data stripes, shared hash pools, under injected-fault
//! plans — delivery must be bit-identical and every planted first-attempt
//! fault detected, for every algorithm. Plus a sim/real cross-check of
//! the concurrent drivers' fault accounting.

use std::sync::Arc;

use fiver::coordinator::scheduler::EngineConfig;
use fiver::coordinator::session::run_parallel_local_transfer;
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::{Fault, FaultPlan};
use fiver::hashes::HashAlgorithm;
use fiver::storage::MemStorage;
use fiver::util::rng::SplitMix64;

/// Build an in-memory source with the given pseudo-random file sizes.
fn mem_src(sizes: &[usize], rng: &mut SplitMix64) -> (MemStorage, Vec<String>, Vec<Vec<u8>>) {
    let storage = MemStorage::new();
    let mut names = Vec::new();
    let mut contents = Vec::new();
    for (i, &size) in sizes.iter().enumerate() {
        let mut data = vec![0u8; size];
        rng.fork().fill_bytes(&mut data);
        let name = format!("e{i:03}");
        storage.put(&name, data.clone());
        names.push(name);
        contents.push(data);
    }
    (storage, names, contents)
}

/// PROPERTY: any dataset + any fault plan (including faults that strike
/// re-transfer attempts) + any algorithm, driven by N concurrent sessions
/// over P stripes => every file lands bit-identical and first-attempt
/// faults are detected.
#[test]
fn prop_engine_recovery_completeness() {
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(seed * 6151 + 3);
        for alg in RealAlgorithm::ALL {
            let n_files = rng.range(3, 9) as usize;
            let mut sizes = Vec::new();
            for _ in 0..n_files {
                let size = match rng.below(4) {
                    0 => 0,
                    1 => rng.range(1, 2_000),
                    2 => rng.range(2_000, 60_000),
                    _ => rng.range(60_000, 400_000),
                };
                sizes.push(size as usize);
            }
            // TransferOnly cannot repair, so it only runs the clean plan.
            let mut faults = FaultPlan::none();
            if alg != RealAlgorithm::TransferOnly {
                for _ in 0..rng.below(4) {
                    let fi = rng.below(n_files as u64) as usize;
                    if sizes[fi] == 0 {
                        continue;
                    }
                    faults.faults.push(Fault {
                        file_idx: fi,
                        offset: rng.below(sizes[fi] as u64),
                        bit: rng.below(8) as u8,
                        occurrence: rng.below(3) as u32,
                    });
                }
            }
            let (src, names, contents) = mem_src(&sizes, &mut rng);
            let dst = MemStorage::new();
            let mut cfg = SessionConfig::new(alg, native_factory(HashAlgorithm::Fvr256));
            cfg.buf_size = rng.range(2_000, 40_000) as usize;
            cfg.block_size = rng.range(30_000, 150_000);
            cfg.queue_capacity = rng.range(8_000, 200_000) as usize;
            cfg.leaf_size = 16_384;
            cfg.hybrid_threshold = 150_000;
            let eng = EngineConfig {
                concurrency: rng.range(2, 4) as usize,
                parallel: rng.range(1, 3) as usize,
                hash_workers: rng.range(1, 3) as usize,
                batch_threshold: 50_000,
                batch_bytes: 120_000,
            };
            let (report, rreports) = run_parallel_local_transfer(
                &names,
                Arc::new(src),
                Arc::new(dst.clone()),
                &cfg,
                &eng,
                &faults,
            )
            .unwrap_or_else(|e| {
                panic!("seed {seed} {} (eng {eng:?}) failed: {e:#}", alg.name())
            });
            let total = report.aggregate();
            assert_eq!(total.files, n_files, "seed {seed} {}", alg.name());
            assert_eq!(rreports.len(), eng.concurrency);
            assert_eq!(
                rreports.iter().map(|r| r.files_received).sum::<usize>(),
                n_files,
                "seed {seed} {}",
                alg.name()
            );
            let first_attempt_faults = faults
                .faults
                .iter()
                .filter(|f| f.occurrence == 0 && sizes[f.file_idx] > 0)
                .count();
            if first_attempt_faults > 0 {
                assert!(
                    total.failures_detected > 0,
                    "seed {seed} {}: {first_attempt_faults} first-attempt faults, none detected",
                    alg.name()
                );
            }
            for (name, expect) in names.iter().zip(&contents) {
                let got = dst
                    .get(name)
                    .unwrap_or_else(|| panic!("seed {seed} {}: missing {name}", alg.name()));
                assert_eq!(
                    &got,
                    expect,
                    "seed {seed} {} c={} p={}: delivered bytes differ on {name}",
                    alg.name(),
                    eng.concurrency,
                    eng.parallel
                );
            }
        }
    }
}

/// Striping correctness at a hostile buffer/queue geometry: P=3 stripes,
/// buffers misaligned with leaves and blocks, faults included.
#[test]
fn engine_three_stripes_hostile_geometry() {
    let mut rng = SplitMix64::new(0x57121);
    let sizes = [333_333usize, 0, 100_001, 65_536, 250_000];
    let mut faults = FaultPlan::none();
    faults.faults.push(Fault { file_idx: 0, offset: 166_000, bit: 1, occurrence: 0 });
    faults.faults.push(Fault { file_idx: 4, offset: 3, bit: 7, occurrence: 0 });
    faults.faults.push(Fault { file_idx: 4, offset: 3, bit: 6, occurrence: 1 });
    for alg in [RealAlgorithm::Fiver, RealAlgorithm::FiverChunk, RealAlgorithm::FiverMerkle] {
        let (src, names, contents) = mem_src(&sizes, &mut rng);
        let dst = MemStorage::new();
        let mut cfg = SessionConfig::new(alg, native_factory(HashAlgorithm::Fvr256));
        cfg.buf_size = 7_777; // misaligned with everything
        cfg.block_size = 100_000;
        cfg.queue_capacity = 20_000; // small: exercises the spill path
        cfg.leaf_size = 16_384;
        let eng = EngineConfig {
            concurrency: 2,
            parallel: 3,
            hash_workers: 2,
            batch_threshold: 0,
            batch_bytes: 1,
        };
        let (report, _) = run_parallel_local_transfer(
            &names,
            Arc::new(src),
            Arc::new(dst.clone()),
            &cfg,
            &eng,
            &faults,
        )
        .unwrap_or_else(|e| panic!("{} failed: {e:#}", alg.name()));
        let total = report.aggregate();
        assert!(total.failures_detected >= 2, "{}: {}", alg.name(), total.failures_detected);
        for (name, expect) in names.iter().zip(&contents) {
            assert_eq!(&dst.get(name).unwrap(), expect, "{} {name}", alg.name());
        }
    }
}

/// Sim/real cross-check at concurrency > 1: the simulated engine
/// ([`fiver::sim::algorithms::run_concurrent`]) and the real engine agree
/// on fault accounting for the same dataset + fault plan (occurrence-0
/// faults, FIVER file-level: one detected failure and one whole-file
/// re-send per faulty file).
#[test]
fn sim_real_cross_check_at_concurrency() {
    use fiver::config::{AlgoParams, Testbed};
    use fiver::sim::algorithms::{run_concurrent, Algorithm};
    use fiver::workload::Dataset;

    let n_files = 6usize;
    let size = 150_000u64;
    let faults = FaultPlan {
        faults: vec![
            Fault { file_idx: 0, offset: 10, bit: 0, occurrence: 0 },
            Fault { file_idx: 2, offset: 149_999, bit: 3, occurrence: 0 },
            Fault { file_idx: 5, offset: 75_000, bit: 5, occurrence: 0 },
        ],
        crash: None,
    };

    // Real engine over loopback.
    let mut rng = SplitMix64::new(0xCAB);
    let sizes = vec![size as usize; n_files];
    let (src, names, contents) = mem_src(&sizes, &mut rng);
    let dst = MemStorage::new();
    let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    let eng = EngineConfig {
        concurrency: 3,
        parallel: 2,
        hash_workers: 3,
        batch_threshold: 0,
        batch_bytes: 1,
    };
    let (report, _) = run_parallel_local_transfer(
        &names,
        Arc::new(src),
        Arc::new(dst.clone()),
        &cfg,
        &eng,
        &faults,
    )
    .unwrap();
    let real = report.aggregate();
    for (name, expect) in names.iter().zip(&contents) {
        assert_eq!(&dst.get(name).unwrap(), expect, "{name}");
    }

    // Simulated engine, same shape and plan.
    let ds = Dataset::uniform("x", size, n_files);
    let params = AlgoParams { batch_threshold: 0, ..AlgoParams::default() };
    let sim = run_concurrent(
        Testbed::hpclab_40g(),
        params,
        &ds,
        &faults,
        Algorithm::Fiver,
        3,
        3,
    );

    assert_eq!(real.failures_detected, sim.failures_detected, "failure accounting diverged");
    assert_eq!(real.failures_detected, 3, "one per faulty file");
    assert_eq!(real.bytes_resent, sim.bytes_resent, "repair traffic diverged");
    assert_eq!(real.bytes_resent, 3 * size, "FIVER re-sends the whole faulty file");
    assert_eq!(
        sim.per_session.iter().map(|s| s.files).sum::<usize>(),
        n_files,
        "sim sessions cover the dataset"
    );
}
