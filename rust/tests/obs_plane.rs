//! Integration: the observability plane — sharded-histogram merge
//! equivalence, trace/metrics export validity, and bottleneck
//! attribution on both the real loopback engine and the sim testbed.

use std::sync::Arc;

use fiver::config::{gbps, AlgoParams, Testbed, MB};
use fiver::coordinator::session::run_local_transfer;
use fiver::coordinator::{native_factory, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::HashAlgorithm;
use fiver::obs::{Hist, HistSnapshot, Recorder, Stage};
use fiver::sim::algorithms::{run, Algorithm};
use fiver::sim::testbed::{Side, SimEnv};
use fiver::storage::MemStorage;
use fiver::util::json::Json;
use fiver::util::rng::SplitMix64;
use fiver::workload::{Dataset, FileSpec};

/// N sharded histograms merged at report time must be bit-identical to a
/// single histogram that saw every sample — counts, sum, and every
/// percentile (the property the per-worker sharding design rests on).
#[test]
fn sharded_histograms_merge_to_single_reference() {
    const SHARDS: usize = 8;
    const SAMPLES: usize = 20_000;
    let shards: Vec<Hist> = (0..SHARDS).map(|_| Hist::new()).collect();
    let reference = Hist::new();
    let mut rng = SplitMix64::new(0x0B5E_7EED);
    for i in 0..SAMPLES {
        // Spread samples across many octaves so most buckets populate.
        let shift = (rng.next_u64() % 60) as u32;
        let v = rng.next_u64() >> shift;
        shards[i % SHARDS].record(v);
        reference.record(v);
    }
    let mut merged = HistSnapshot::default();
    for s in &shards {
        merged.merge(&s.snapshot());
    }
    let expect = reference.snapshot();
    assert_eq!(merged, expect, "merged shards must equal the single-shard reference");
    assert_eq!(merged.count(), SAMPLES as u64);
    for p in 1..=99 {
        assert_eq!(
            merged.percentile(p as f64),
            expect.percentile(p as f64),
            "percentile {p} diverged"
        );
    }
}

/// The Chrome/Perfetto export is well-formed trace_event JSON: a
/// traceEvents array of thread-name metadata plus "X" complete events
/// with microsecond ts/dur. The metrics export parses too.
#[test]
fn chrome_trace_and_metrics_exports_are_valid_json() {
    let rec = Recorder::enabled();
    let shard = rec.shard("test-worker");
    shard.record_ns(Stage::Read, 1_000, 5_000);
    shard.record_ns(Stage::Hash, 6_000, 250_000);
    shard.record_ns(Stage::Send, 10_000, 42_000);
    let mut buf: Vec<u8> = Vec::new();
    rec.write_chrome_trace(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let doc = Json::parse(&text).unwrap_or_else(|e| panic!("invalid trace JSON: {e:?}\n{text}"));
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    let mut complete = 0usize;
    let mut metadata = 0usize;
    for ev in events {
        match ev.get("ph").and_then(|p| p.as_str()) {
            Some("X") => {
                complete += 1;
                assert!(ev.get("name").and_then(|n| n.as_str()).is_some(), "X event name");
                assert!(ev.get("ts").and_then(|t| t.as_f64()).is_some(), "X event ts");
                assert!(ev.get("dur").and_then(|d| d.as_f64()).is_some(), "X event dur");
            }
            Some("M") => metadata += 1,
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert_eq!(complete, 3, "one X event per recorded span");
    assert!(metadata >= 1, "thread_name metadata for the shard");
    let metrics = rec.metrics_json();
    let mdoc = Json::parse(&metrics)
        .unwrap_or_else(|e| panic!("invalid metrics JSON: {e:?}\n{metrics}"));
    assert!(mdoc.get("stages").is_some(), "metrics carry per-stage histograms");
    assert!(mdoc.get("bottleneck").is_some(), "metrics carry the attribution");
    assert!(
        mdoc.get("confidence").and_then(|c| c.as_f64()).is_some(),
        "multi-group run renders a numeric confidence: {metrics}"
    );
}

/// A run where only one stage group recorded anything has no runner-up
/// to ratio against: the metrics export must emit `"confidence":null`
/// (valid JSON), never a bare `inf` or the old `999.0` sentinel.
#[test]
fn sole_group_confidence_exports_as_json_null() {
    let rec = Recorder::enabled();
    let shard = rec.shard("hash-worker");
    shard.record_ns(Stage::Hash, 0, 1_000_000);
    let rep = rec.report();
    assert_eq!(rep.bottleneck, "hash-bound");
    assert!(rep.confidence.is_infinite(), "sole group: {}", rep.confidence);
    let metrics = rec.metrics_json();
    let mdoc = Json::parse(&metrics)
        .unwrap_or_else(|e| panic!("invalid metrics JSON: {e:?}\n{metrics}"));
    assert_eq!(mdoc.get("confidence"), Some(&Json::Null), "{metrics}");
}

/// A SHA1-heavy loopback transfer is hash-bound: both endpoints digest
/// every byte while storage is memcpy-fast, so the attribution must
/// blame the checksum stations (the regime Eq. 1's `t_chksum >
/// t_transfer` describes).
#[test]
fn loopback_sha1_run_attributes_hash_bound() {
    let src = MemStorage::new();
    let mut rng = SplitMix64::new(0xC0FFEE);
    let mut names = Vec::new();
    for i in 0..4 {
        let mut data = vec![0u8; 2 * 1024 * 1024];
        rng.fill_bytes(&mut data);
        let name = format!("f{i}");
        src.put(&name, data);
        names.push(name);
    }
    let mut cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Sha1));
    cfg.obs = Recorder::enabled();
    let (report, _rreport) = run_local_transfer(
        &names,
        Arc::new(src),
        Arc::new(MemStorage::new()),
        &cfg,
        &FaultPlan::none(),
    )
    .expect("loopback transfer");
    assert!(!report.stage_stats.is_empty(), "tracing was on: stage stats must be populated");
    let hash = report.stage_stats.iter().find(|s| s.stage == "hash");
    assert!(hash.map(|s| s.count > 0).unwrap_or(false), "hash spans recorded: {report:?}");
    assert_eq!(
        report.bottleneck, "hash-bound",
        "SHA1 loopback must be hash-bound (stages: {:?})",
        report.stage_stats
    );
    assert!(report.bottleneck_confidence >= 1.0);
}

/// The same attribution on the sim testbed: throttle the link far below
/// the hash rate and the run must flip to net-bound.
#[test]
fn sim_throttled_link_attributes_net_bound() {
    let mut tb = Testbed::hpclab_40g();
    tb.bandwidth = gbps(0.3); // hash cores run at ~3 Gbps: net is 10x slower
    let ds = Dataset::uniform("1G", 1024 * MB, 2);
    let s = run(tb, AlgoParams::default(), &ds, &FaultPlan::none(), Algorithm::Fiver);
    assert_eq!(s.bottleneck, "net-bound", "stages: {:?}", s.stage_stats);
    assert!(s.bottleneck_confidence > 2.0, "confidence {}", s.bottleneck_confidence);
}

/// And without the throttle, HPCLab-40G's FIVER runs are hash-bound in
/// the sim exactly as the paper describes (hash is the slowest stage).
#[test]
fn sim_default_40g_attributes_hash_bound() {
    let ds = Dataset::uniform("1G", 1024 * MB, 2);
    let s = run(
        Testbed::hpclab_40g(),
        AlgoParams::default(),
        &ds,
        &FaultPlan::none(),
        Algorithm::Fiver,
    );
    assert_eq!(s.bottleneck, "hash-bound", "stages: {:?}", s.stage_stats);
}

/// Sim spans are deterministic: two identical virtual-time runs emit
/// identical span streams (which is why the recorder bans wall-clock
/// lookups in sim paths).
#[test]
fn sim_spans_are_deterministic() {
    let spans_of = || {
        let mut e = SimEnv::new(Testbed::hpclab_40g(), AlgoParams::default());
        e.enable_tracing();
        let a = FileSpec { id: 0, name: "a".into(), size: 256 * MB };
        let b = FileSpec { id: 1, name: "b".into(), size: 64 * MB };
        let fa = e.start_fiver_flow(&a, 0, a.size);
        e.pump_until(fa);
        let ck = e.start_checksum(Side::Dst, &b, 0, b.size, false);
        e.pump_until(ck);
        e.sim_spans()
    };
    let first = spans_of();
    let second = spans_of();
    assert!(!first.is_empty(), "flows must record spans");
    assert_eq!(first, second, "same seed, same virtual time, same spans");
}
