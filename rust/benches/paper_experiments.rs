//! Bench: regenerate every paper table/figure and time the simulation —
//! one bench entry per experiment (the `cargo bench` face of
//! `repro-experiments all`). Reports simulator wall time per figure; the
//! figures' *contents* go to stdout via the repro-experiments binary and
//! EXPERIMENTS.md.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, black_box, pick};

fn main() {
    println!("== paper experiment regeneration (simulation wall time) ==");
    for name in fiver::experiments::ALL {
        let r = bench(&format!("experiment/{name}"), 0, 1, || {
            black_box(fiver::experiments::run_by_name(name).unwrap().len());
        });
        r.report_time();
    }

    // Simulator micro-benchmark: fluid-engine event throughput.
    println!("\n== fluid engine ==");
    use fiver::config::{AlgoParams, Testbed, MB};
    use fiver::faults::FaultPlan;
    use fiver::sim::algorithms::{run, Algorithm};
    use fiver::workload::Dataset;
    let files = pick(500, 100);
    let ds = Dataset::uniform("10M", 10 * MB, files);
    let r = bench(&format!("sim/sequential-{files}-files"), 1, pick(3, 1), || {
        black_box(run(
            Testbed::esnet_wan(),
            AlgoParams::default(),
            &ds,
            &FaultPlan::none(),
            Algorithm::Sequential,
        ));
    });
    r.report_ops(files as u64);
    let r = bench(&format!("sim/fiver-{files}-files"), 1, pick(3, 1), || {
        black_box(run(
            Testbed::esnet_wan(),
            AlgoParams::default(),
            &ds,
            &FaultPlan::none(),
            Algorithm::Fiver,
        ));
    });
    r.report_ops(files as u64);

    // The engine counterpart: the same dataset at concurrency 8.
    let r = bench(&format!("sim/fiver-c8-{files}-files"), 1, pick(3, 1), || {
        black_box(fiver::sim::algorithms::run_concurrent(
            Testbed::esnet_wan(),
            AlgoParams::default(),
            &ds,
            &FaultPlan::none(),
            Algorithm::Fiver,
            8,
            8,
        ));
    });
    r.report_ops(files as u64);
}
