//! Bench: Merkle tree-build overhead per GB against plain FIVER hashing.
//!
//! FIVER-Merkle folds leaf digests into a binary tree as the stream drains
//! from the shared queue; the extra work over a single running digest is
//! one finalize/reset per leaf plus ~2x leaf-count short combine hashes.
//! Target: <2% throughput cost at 64 KiB leaves (the repair-granularity
//! sweet spot — smaller leaves shrink repairs but add per-leaf overhead).

#[path = "bench_util.rs"]
mod bench_util;

use std::sync::Arc;

use bench_util::{bench, black_box, pick};
use fiver::hashes::HashAlgorithm;
use fiver::merkle::MerkleBuilder;
use fiver::util::rng::SplitMix64;

fn main() {
    let mb = 1usize << 20;
    let size = pick(256, 32) * mb; // scaled sample; per-GB figures derive linearly
    let iters = pick(5, 2);
    let buf = 256 * 1024; // the coordinator's default I/O buffer
    let mut data = vec![0u8; size];
    SplitMix64::new(2).fill_bytes(&mut data);

    for alg in [HashAlgorithm::Fvr256, HashAlgorithm::Md5] {
        println!("== {} ({} MiB stream, {} KiB buffers) ==", alg.name(), size / mb, buf / 1024);

        // Baseline: plain FIVER — one running digest over the stream.
        let base = bench(&format!("{}/plain-fiver", alg.name()), 1, iters, || {
            let mut h = alg.hasher();
            for part in data.chunks(buf) {
                h.update(part);
            }
            black_box(h.finalize());
        });
        base.report_bytes(size as u64);

        // Tree builds across leaf sizes.
        for leaf_kib in [16u64, 64, 256, 1024] {
            let factory: fiver::merkle::DigestFactory = Arc::new(move || alg.hasher());
            let r = bench(&format!("{}/merkle-{}KiB-leaves", alg.name(), leaf_kib), 1, iters, || {
                let mut b = MerkleBuilder::new(leaf_kib << 10, factory.clone());
                for part in data.chunks(buf) {
                    b.update(part);
                }
                black_box(b.finish().root().to_vec());
            });
            r.report_bytes(size as u64);
            let overhead = r.median_secs / base.median_secs - 1.0;
            println!(
                "    overhead vs plain: {:>6.2}% {}",
                overhead * 100.0,
                if leaf_kib == 64 && overhead > 0.02 { "(!! target <2% at 64 KiB)" } else { "" }
            );
        }
        println!();
    }
}
