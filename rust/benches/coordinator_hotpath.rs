//! Bench: the L3 coordinator hot path — queue handoff, frame
//! encode/decode, complete loopback transfers per algorithm, and the
//! parallel engine (the real-mode counterpart of the paper's throughput
//! claims plus the concurrency scale-out).

#[path = "bench_util.rs"]
mod bench_util;

use std::sync::Arc;

use bench_util::{bench, black_box, pick};
use fiver::coordinator::bufpool::{BufferPool, SharedBuf};
use fiver::coordinator::queue::ByteQueue;
use fiver::coordinator::scheduler::EngineConfig;
use fiver::coordinator::session::{run_local_transfer, run_parallel_local_transfer};
use fiver::coordinator::{native_factory, protocol, RealAlgorithm, SessionConfig};
use fiver::faults::FaultPlan;
use fiver::hashes::HashAlgorithm;
use fiver::obs::{Hist, Recorder, Stage};
use fiver::storage::{FsStorage, IoBackend, MemStorage, Storage};
use fiver::util::rng::SplitMix64;

fn main() {
    queue_bench();
    queue_pool_bench();
    protocol_bench();
    obs_bench();
    storage_backend_bench();
    transfer_bench();
    engine_bench();
}

/// The paper's Algorithm 1/2 queue: producer/consumer handoff rate.
fn queue_bench() {
    let total = pick(64, 8) << 20;
    println!("== ByteQueue ({} MiB through an 8 MiB queue, 256 KiB buffers) ==", total >> 20);
    let buf_size = 256 * 1024;
    let r = bench("queue/produce+consume", 1, pick(5, 2), || {
        let q = ByteQueue::new(8 << 20);
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            let buf = vec![0u8; buf_size];
            for _ in 0..(total / buf_size) {
                q2.add(SharedBuf::from_vec(buf.clone()));
            }
            q2.close();
        });
        let mut consumed = 0usize;
        while let Some(b) = q.remove() {
            consumed += b.len();
        }
        producer.join().unwrap();
        black_box(consumed);
    });
    r.report_bytes(total as u64);
}

/// Owned-Vec vs pooled buffers through the queue: the allocator cost the
/// zero-copy data plane removes. "owned" allocates + fills a fresh Vec
/// per buffer (the pre-pool hot path); "pooled" recycles `BufferPool`
/// backings and shares them into the queue by refcount.
fn queue_pool_bench() {
    let total = pick(64, 8) << 20;
    let buf_size = 256 * 1024;
    let count = total / buf_size;
    println!(
        "\n== queue+pool ({} MiB, 256 KiB buffers, owned Vec vs pooled SharedBuf) ==",
        total >> 20
    );
    let r = bench("queue/owned-vec", 1, pick(5, 2), || {
        let q = ByteQueue::new(8 << 20);
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..count {
                // Fresh allocation + fill per buffer — the old data plane.
                let mut buf = vec![0u8; buf_size];
                buf[0] = i as u8;
                q2.add(SharedBuf::from_vec(buf));
            }
            q2.close();
        });
        let mut consumed = 0usize;
        while let Some(b) = q.remove() {
            consumed += b.len();
        }
        producer.join().unwrap();
        black_box(consumed);
    });
    r.report_bytes(total as u64);

    let pool = BufferPool::new(buf_size, 48);
    let r = bench("queue/pooled", 1, pick(5, 2), || {
        let q = ByteQueue::new(8 << 20);
        let q2 = q.clone();
        let pool2 = pool.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..count {
                let mut buf = pool2.get();
                buf[0] = i as u8;
                q2.add(buf.freeze(buf_size));
            }
            q2.close();
        });
        let mut consumed = 0usize;
        while let Some(b) = q.remove() {
            consumed += b.len();
        }
        producer.join().unwrap();
        black_box(consumed);
    });
    r.report_bytes(total as u64);
    println!(
        "   pool steady state: {} backings allocated for {} buffer cycles",
        pool.allocated(),
        count * pick(5, 2).max(1)
    );
}

fn protocol_bench() {
    println!("\n== protocol framing (256 KiB Data frames) ==");
    let payload = vec![0xABu8; 256 * 1024];
    let frames = pick(256, 32);
    let r = bench("protocol/encode", 2, pick(10, 3), || {
        let mut out = Vec::with_capacity(frames * (payload.len() + 32));
        for i in 0..frames {
            protocol::write_data_frame(&mut out, 1, (i * payload.len()) as u64, &payload).unwrap();
        }
        black_box(out.len());
    });
    r.report_bytes((frames * payload.len()) as u64);

    let mut encoded = Vec::new();
    for i in 0..frames {
        protocol::write_data_frame(&mut encoded, 1, (i * payload.len()) as u64, &payload).unwrap();
    }
    let r = bench("protocol/decode", 2, pick(10, 3), || {
        let mut cursor = &encoded[..];
        let mut n = 0;
        while let Some(f) = protocol::Frame::read_from(&mut cursor).unwrap() {
            if let protocol::Frame::Data { payload, .. } = f {
                n += payload.len();
            }
        }
        black_box(n);
    });
    r.report_bytes((frames * payload.len()) as u64);

    // Same stream decoded into recycled pool backings (the receiver's
    // stripe-reader path): no per-frame payload allocation.
    let pool = BufferPool::new(256 * 1024, 4);
    let r = bench("protocol/decode-pooled", 2, pick(10, 3), || {
        let mut cursor = &encoded[..];
        let mut n = 0;
        while let Some(f) = protocol::Frame::read_from_pooled(&mut cursor, &pool).unwrap() {
            if let protocol::Frame::Data { payload, .. } = f {
                n += payload.len();
            }
        }
        black_box(n);
    });
    r.report_bytes((frames * payload.len()) as u64);
}

/// The observability plane's own cost: raw span/histogram record rates in
/// isolation, then the end-to-end tracing tax — the same loopback FIVER
/// transfer with the recorder off vs on. Target: <2% median wall-clock
/// delta (the CI bench gate compares the two recorded medians).
fn obs_bench() {
    println!("\n== observability plane (span/hist record rates, tracing tax) ==");
    let ops = pick(4 << 20, 1 << 18);
    let rec = Recorder::enabled();
    let shard = rec.shard("bench");
    let r = bench("obs/span-record", 2, pick(10, 3), || {
        for i in 0..ops {
            shard.record_ns(Stage::Hash, i as u64, 1_000);
        }
    });
    r.report_ops(ops as u64);

    let hist = Hist::new();
    let r = bench("obs/hist-record", 2, pick(10, 3), || {
        for i in 0..ops {
            hist.record(i as u64);
        }
    });
    r.report_ops(ops as u64);

    let count = pick(16, 4);
    let size = 1usize << 20;
    let total = (count * size) as u64;
    let src = MemStorage::new();
    let mut rng = SplitMix64::new(13);
    let mut names = Vec::new();
    for i in 0..count {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        let name = format!("o{i}");
        src.put(&name, data);
        names.push(name);
    }
    let mut medians = [0.0f64; 2];
    for (slot, tracing) in [(0usize, false), (1, true)] {
        let label =
            if tracing { "transfer/FIVER-tracing-on" } else { "transfer/FIVER-tracing-off" };
        let src = src.clone();
        let names = names.clone();
        let r = bench(label, 1, pick(5, 2), || {
            let mut cfg =
                SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
            // Pin the recorder explicitly: FIVER_TRACE in the environment
            // must not turn the "off" baseline on.
            cfg.obs = if tracing { Recorder::enabled() } else { Recorder::disabled() };
            let dst = MemStorage::new();
            let (rep, _) = run_local_transfer(
                &names,
                Arc::new(src.clone()),
                Arc::new(dst),
                &cfg,
                &FaultPlan::none(),
            )
            .unwrap();
            black_box(rep.bytes_sent);
        });
        medians[slot] = r.median_secs;
        r.report_bytes(total);
    }
    println!(
        "   tracing tax: {:+.2}% median wall-clock (budget: < 2%)",
        (medians[1] / medians[0] - 1.0) * 100.0
    );
}

/// The storage engines head to head on their hot paths: sequential
/// write (+ one sync), ranged `read_shared` reads (pooled fill vs mmap
/// zero-copy view vs O_DIRECT aligned read), and a full FsStorage
/// loopback FIVER transfer per backend. Engines a filesystem refuses
/// degrade gracefully inside the backend — the numbers then document the
/// fallback, which is itself worth seeing in bench-results.json.
fn storage_backend_bench() {
    let total = pick(64, 8) << 20;
    let buf_size = 256 * 1024;
    println!(
        "\n== storage backends ({} MiB, 256 KiB ops, FsStorage read/write) ==",
        total >> 20
    );
    let payload = vec![0xA5u8; buf_size];
    for backend in IoBackend::ALL {
        let dir = fiver::util::tmpdir::unique_dir(&format!("fiver-bench-{}", backend.name()));
        let storage = FsStorage::with_backend(&dir, backend).unwrap();
        let pool = BufferPool::with_options(buf_size, 8, backend.buffer_align(), 8);
        // Lets the uring engine pin the pool's backings as registered
        // buffers, so its reads take the READ_FIXED path (no-op elsewhere).
        storage.register_pool(&pool);
        let r = bench(&format!("storage/write-{}", backend.name()), 1, pick(3, 1), || {
            let mut w = storage.open_write_sized("f", total as u64).unwrap();
            for _ in 0..(total / buf_size) {
                w.write_next(&payload).unwrap();
            }
            w.flush().unwrap();
            w.sync().unwrap();
        });
        r.report_bytes(total as u64);
        let r = bench(&format!("storage/read-{}", backend.name()), 1, pick(3, 1), || {
            let mut rd = storage.open_read("f").unwrap();
            let mut off = 0u64;
            let mut n = 0usize;
            while off < total as u64 {
                let chunk = rd.read_shared(off, buf_size, &pool).unwrap();
                if chunk.is_empty() {
                    break;
                }
                n += chunk.len();
                off += chunk.len() as u64;
            }
            black_box(n);
        });
        r.report_bytes(total as u64);
        if backend == IoBackend::Uring {
            let (enters, ops) = (storage.uring_enters(), storage.uring_ops());
            if storage.uring_fallbacks() == 0 && ops > 0 {
                // The whole point of the uring engine: readahead batches
                // amortize the enter syscall over several chunks.
                assert!(enters < ops, "uring batching regressed: {enters} enters for {ops} ops");
                println!("  uring batching: {ops} ops in {enters} enter syscalls");
            } else {
                println!("  uring unavailable here — batching not measured (buffered fallback)");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    // End to end per backend: a loopback FIVER engine transfer with
    // FsStorage on both ends (the receiver's decode/write path and the
    // sender's read path both ride the selected engine).
    let count = pick(16, 4);
    let size = 1usize << 20;
    let grand = (count * size) as u64;
    println!("\n== per-backend loopback ({count} x 1 MiB, FsStorage, fvr256) ==");
    let mut rng = SplitMix64::new(11);
    let mut datas = Vec::with_capacity(count);
    for _ in 0..count {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        datas.push(data);
    }
    for backend in IoBackend::ALL {
        let dir = fiver::util::tmpdir::unique_dir(&format!("fiver-bxfer-{}", backend.name()));
        let src = FsStorage::with_backend(&dir.join("src"), backend).unwrap();
        let mut names = Vec::with_capacity(count);
        for (i, data) in datas.iter().enumerate() {
            let name = format!("b{i}");
            let mut w = src.open_write(&name).unwrap();
            w.write_next(data).unwrap();
            w.flush().unwrap();
            names.push(name);
        }
        let src = Arc::new(src);
        let label = format!("transfer/FIVER-fs-{}", backend.name());
        let r = bench(&label, 1, pick(3, 1), || {
            let mut cfg =
                SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
            cfg.io_backend = backend;
            let dst: Arc<dyn Storage> =
                Arc::new(FsStorage::with_backend(&dir.join("dst"), backend).unwrap());
            let (rep, _) =
                run_local_transfer(&names, src.clone(), dst, &cfg, &FaultPlan::none()).unwrap();
            black_box(rep.bytes_sent);
        });
        r.report_bytes(grand);
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Complete loopback sessions: what a user of the system sees.
fn transfer_bench() {
    let sizes = vec![pick(4, 1) << 20; pick(16, 4)];
    let total: usize = sizes.iter().sum();
    println!(
        "\n== loopback transfer ({} x {} MiB, MemStorage, fvr256) ==",
        sizes.len(),
        sizes[0] >> 20
    );
    let src = MemStorage::new();
    let mut rng = SplitMix64::new(3);
    let mut names = Vec::new();
    for (i, &s) in sizes.iter().enumerate() {
        let mut data = vec![0u8; s];
        rng.fill_bytes(&mut data);
        let name = format!("b{i}");
        src.put(&name, data);
        names.push(name);
    }
    // FiverHybrid is skipped: at these sizes it is Fiver with extra setup.
    for alg in RealAlgorithm::ALL.into_iter().filter(|a| *a != RealAlgorithm::FiverHybrid) {
        let src = src.clone();
        let names = names.clone();
        let r = bench(&format!("transfer/{}", alg.name()), 1, pick(3, 1), || {
            let cfg = SessionConfig::new(alg, native_factory(HashAlgorithm::Fvr256));
            let dst = MemStorage::new();
            let (rep, _) = run_local_transfer(
                &names,
                Arc::new(src.clone()),
                Arc::new(dst),
                &cfg,
                &FaultPlan::none(),
            )
            .unwrap();
            black_box(rep.bytes_sent);
        });
        r.report_bytes(total as u64);
    }
}

/// The tentpole scale-out: the same dataset through the parallel engine
/// at increasing concurrency (shared hash pool sized to match).
fn engine_bench() {
    let count = pick(48, 12);
    let size = 1usize << 20;
    let total = (count * size) as u64;
    println!("\n== parallel engine ({count} x 1 MiB, MemStorage, fvr256) ==");
    let src = MemStorage::new();
    let mut rng = SplitMix64::new(7);
    let mut names = Vec::new();
    for i in 0..count {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        let name = format!("p{i}");
        src.put(&name, data);
        names.push(name);
    }
    let cfg = SessionConfig::new(RealAlgorithm::Fiver, native_factory(HashAlgorithm::Fvr256));
    for concurrency in [1usize, 2, 4, 8] {
        let src = src.clone();
        let names = names.clone();
        let cfg = cfg.clone();
        let label = format!("engine/FIVER-c{concurrency}");
        let r = bench(&label, 1, pick(3, 1), || {
            let eng = EngineConfig {
                concurrency,
                parallel: 1,
                hash_workers: concurrency.max(2),
                batch_threshold: 0,
                batch_bytes: 1,
            };
            let dst = MemStorage::new();
            let (rep, _) = run_parallel_local_transfer(
                &names,
                Arc::new(src.clone()),
                Arc::new(dst),
                &cfg,
                &eng,
                &FaultPlan::none(),
            )
            .unwrap();
            black_box(rep.aggregate().bytes_sent);
        });
        r.report_bytes(total);
    }
}
