//! Bench: hash throughput — the paper's central rate (its testbeds hash
//! MD5 at ~3 Gbps/core; FIVER's benefit depends on where hashing sits
//! relative to the network). Covers the from-scratch MD5/SHA-1/SHA-256,
//! the native FVR-256 port, and FVR-256 through the XLA/PJRT artifact
//! (Pallas-kernel and jnp-reference lowerings).

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, black_box, pick};
use fiver::hashes::HashAlgorithm;
use fiver::util::rng::SplitMix64;

fn main() {
    let mb = 1 << 20;
    let size = pick(64, 4) * mb;
    let iters = pick(5, 2);
    let mut data = vec![0u8; size];
    SplitMix64::new(1).fill_bytes(&mut data);

    println!("== hash throughput ({} MiB buffer) ==", size / mb);
    for alg in HashAlgorithm::ALL {
        let r = bench(&format!("native/{}", alg.name()), 1, iters, || {
            let mut h = alg.hasher();
            h.update(&data);
            black_box(h.finalize());
        });
        r.report_bytes(size as u64);
    }

    // Streaming at transfer buffer granularity (the coordinator hot path).
    println!("\n== streaming update granularity (fvr256, {} MiB total) ==", size / mb);
    for buf in [64 * 1024, 256 * 1024, 1 << 20, 4 << 20] {
        let r = bench(&format!("fvr256/update-{}KiB", buf / 1024), 1, iters, || {
            let mut h = HashAlgorithm::Fvr256.hasher();
            for part in data.chunks(buf) {
                h.update(part);
            }
            black_box(h.finalize());
        });
        r.report_bytes(size as u64);
    }

    // XLA/PJRT path: per-chunk artifact execution (interpret-mode Pallas on
    // CPU — correctness path; real-TPU perf is estimated structurally in
    // DESIGN.md §10).
    match fiver::runtime::find_artifacts_dir()
        .and_then(|d| fiver::runtime::Manifest::load(&d))
    {
        Ok(manifest) => {
            println!("\n== XLA/PJRT chunk digest (one 256 KiB chunk) ==");
            for (variant, use_ref) in [("256k", false), ("256k", true)] {
                let engine =
                    fiver::runtime::XlaHashEngine::load(&manifest, variant, use_ref).unwrap();
                let chunk = &data[..engine.geometry().chunk_bytes()];
                let label = format!("xla/{}", engine.name());
                let r = bench(&label, 1, 3, || {
                    black_box(engine.chunk_digest_bytes(chunk, 0).unwrap());
                });
                r.report_bytes(chunk.len() as u64);
            }
        }
        Err(_) => println!("\n(xla benches skipped: run `make artifacts`)"),
    }
}
