//! Bench: hash throughput — the paper's central rate (its testbeds hash
//! MD5 at ~3 Gbps/core; FIVER's benefit depends on where hashing sits
//! relative to the network). Covers the from-scratch MD5/SHA-1/SHA-256,
//! the native FVR-256 port, and FVR-256 through the XLA/PJRT artifact
//! (Pallas-kernel and jnp-reference lowerings).

#[path = "bench_util.rs"]
mod bench_util;

use std::sync::Arc;

use bench_util::{bench, black_box, pick};
use fiver::hashes::{DigestFactory, HashAlgorithm};
use fiver::merkle::MerkleBuilder;
use fiver::util::rng::SplitMix64;

fn main() {
    let mb = 1 << 20;
    let size = pick(64, 4) * mb;
    let iters = pick(5, 2);
    let mut data = vec![0u8; size];
    SplitMix64::new(1).fill_bytes(&mut data);

    println!("== hash throughput ({} MiB buffer) ==", size / mb);
    for alg in HashAlgorithm::ALL {
        let r = bench(&format!("native/{}", alg.name()), 1, iters, || {
            let mut h = alg.hasher();
            h.update(&data);
            black_box(h.finalize());
        });
        r.report_bytes(size as u64);
    }

    // Tiered-FIVER composition (`--hash-tier`): 64 KiB leaf digests
    // folded under a root, cryptographic-everything vs xxh3-128 leaves
    // under a sha1 root vs fast-everything. The tiered row is the
    // engine's verified-transfer hot path; the acceptance bar is >= 2x
    // the sha1 leaf rate.
    println!("\n== tiered FIVER: 64 KiB leaves + root fold ({} MiB) ==", size / mb);
    let factory = |alg: HashAlgorithm| -> DigestFactory { Arc::new(move || alg.hasher()) };
    let tiers: [(&str, HashAlgorithm, HashAlgorithm, bool); 3] = [
        ("fiver/leaves+root sha1 (cryptographic)", HashAlgorithm::Sha1, HashAlgorithm::Sha1, false),
        ("fiver/leaves xxh3-128, root sha1 (tiered)", HashAlgorithm::Xxh3128, HashAlgorithm::Sha1, true),
        ("fiver/leaves+root xxh3-128 (fast)", HashAlgorithm::Xxh3128, HashAlgorithm::Xxh3128, false),
    ];
    for (label, leaf_alg, node_alg, rooted) in tiers {
        let r = bench(label, 1, iters, || {
            let mut b = MerkleBuilder::new(64 * 1024, factory(leaf_alg))
                .with_tree_hasher(factory(node_alg), rooted);
            b.update(&data);
            black_box(b.finish());
        });
        r.report_bytes(size as u64);
    }

    // Streaming at transfer buffer granularity (the coordinator hot path).
    println!("\n== streaming update granularity (fvr256, {} MiB total) ==", size / mb);
    for buf in [64 * 1024, 256 * 1024, 1 << 20, 4 << 20] {
        let r = bench(&format!("fvr256/update-{}KiB", buf / 1024), 1, iters, || {
            let mut h = HashAlgorithm::Fvr256.hasher();
            for part in data.chunks(buf) {
                h.update(part);
            }
            black_box(h.finalize());
        });
        r.report_bytes(size as u64);
    }

    // XLA/PJRT path: per-chunk artifact execution (interpret-mode Pallas on
    // CPU — correctness path; real-TPU perf is estimated structurally in
    // DESIGN.md §10).
    match fiver::runtime::find_artifacts_dir()
        .and_then(|d| fiver::runtime::Manifest::load(&d))
    {
        Ok(manifest) => {
            println!("\n== XLA/PJRT chunk digest (one 256 KiB chunk) ==");
            for (variant, use_ref) in [("256k", false), ("256k", true)] {
                let engine =
                    fiver::runtime::XlaHashEngine::load(&manifest, variant, use_ref).unwrap();
                let chunk = &data[..engine.geometry().chunk_bytes()];
                let label = format!("xla/{}", engine.name());
                let r = bench(&label, 1, 3, || {
                    black_box(engine.chunk_digest_bytes(chunk, 0).unwrap());
                });
                r.report_bytes(chunk.len() as u64);
            }
        }
        Err(_) => println!("\n(xla benches skipped: run `make artifacts`)"),
    }
}
