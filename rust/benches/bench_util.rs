//! Minimal benchmarking helper (criterion is unavailable offline):
//! warmup + N timed iterations, reporting median / mean / min and derived
//! throughput. Deterministic iteration counts keep `cargo bench` output
//! stable enough for the before/after records in EXPERIMENTS.md §Perf.
//!
//! CI hooks: `BENCH_SMOKE=1` switches benches to quick mode (small sizes
//! and iteration counts via [`pick`]) so the smoke job finishes fast, and
//! `BENCH_JSON=<path>` appends one JSON object per reported result to
//! that file (the workflow uploads it as an artifact).

use std::time::Instant;

/// Quick-mode switch for the CI smoke job.
#[allow(dead_code)]
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// `full` normally, `quick` under `BENCH_SMOKE=1`.
#[allow(dead_code)]
pub fn pick(full: usize, quick: usize) -> usize {
    if smoke() {
        quick
    } else {
        full
    }
}

/// Append one JSON line to `$BENCH_JSON` (no-op when unset).
fn append_json(line: &str) {
    let Ok(path) = std::env::var("BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        writeln!(f, "{line}").ok();
    }
}

#[allow(dead_code)]
pub struct BenchResult {
    pub name: String,
    pub median_secs: f64,
    pub mean_secs: f64,
    pub min_secs: f64,
}

#[allow(dead_code)]
impl BenchResult {
    /// Report with a throughput figure derived from `bytes` per iteration.
    pub fn report_bytes(&self, bytes: u64) {
        let gbps = bytes as f64 * 8.0 / self.median_secs / 1e9;
        let mibs = bytes as f64 / self.median_secs / (1 << 20) as f64;
        println!(
            "{:<44} median {:>10.3} ms   {:>9.1} MiB/s ({:>6.2} Gbps)",
            self.name,
            self.median_secs * 1e3,
            mibs,
            gbps
        );
        self.emit_json(&format!(",\"bytes\":{bytes},\"gbps\":{gbps:.4}"));
    }

    /// Report with an ops/sec figure derived from `ops` per iteration.
    pub fn report_ops(&self, ops: u64) {
        let ops_per_sec = ops as f64 / self.median_secs;
        println!(
            "{:<44} median {:>10.3} ms   {:>12.0} ops/s",
            self.name,
            self.median_secs * 1e3,
            ops_per_sec
        );
        self.emit_json(&format!(",\"ops\":{ops},\"ops_per_sec\":{ops_per_sec:.2}"));
    }

    /// Report raw time only.
    pub fn report_time(&self) {
        println!(
            "{:<44} median {:>10.3} ms  (min {:.3} ms, mean {:.3} ms)",
            self.name,
            self.median_secs * 1e3,
            self.min_secs * 1e3,
            self.mean_secs * 1e3
        );
        self.emit_json("");
    }

    /// One JSON object per result; bench names are plain ASCII so no
    /// escaping is needed. Every line records the environment's I/O
    /// backend (`FIVER_IO_BACKEND`, `buffered` default) and hash tier
    /// (`FIVER_HASH_TIER`, `cryptographic` default) so the CI delta
    /// gate only ever compares like-for-like baselines across the
    /// io-backend and hash-tier matrix legs.
    fn emit_json(&self, extra: &str) {
        // Canonical parse (not the raw env string): alias spellings and
        // unknown values must not defeat the like-for-like comparison.
        let backend = fiver::storage::IoBackend::from_env().name();
        let tier = fiver::hashes::HashTier::from_env().name();
        append_json(&format!(
            "{{\"name\":\"{}\",\"io_backend\":\"{backend}\",\"hash_tier\":\"{tier}\",\
             \"median_secs\":{:.9},\
             \"mean_secs\":{:.9},\"min_secs\":{:.9}{extra}}}",
            self.name,
            self.median_secs,
            self.mean_secs,
            self.min_secs
        ));
    }
}

/// Run `f` `iters` times after `warmup` runs; returns timing stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        median_secs: samples[samples.len() / 2],
        mean_secs: samples.iter().sum::<f64>() / samples.len() as f64,
        min_secs: samples[0],
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
