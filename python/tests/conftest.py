"""Pytest wiring: make the ``compile`` package importable regardless of
the invocation directory (`python -m pytest python/tests` from the repo
root, or pytest from within python/)."""

import sys
from pathlib import Path

# python/ — the directory holding the `compile` package.
_PKG_ROOT = str(Path(__file__).resolve().parents[1])
if _PKG_ROOT not in sys.path:
    sys.path.insert(0, _PKG_ROOT)
