"""L1 correctness: Pallas kernel vs pure-jnp oracle vs plain-python spec.

The core signal of the whole stack: if these pass, the HLO artifacts the
Rust runtime executes encode exactly the FVR-256 the Rust port computes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fvr_hash, ref
from compile.kernels.fvr_hash import IV, LANES


def rand_chunk(rng, num_blocks, wpb):
    return rng.randint(0, 2**32, size=(num_blocks, wpb), dtype=np.uint32)


# ---------------------------------------------------------------------------
# absorb8 round function
# ---------------------------------------------------------------------------

class TestAbsorb8:
    def test_jnp_matches_python(self):
        rng = np.random.RandomState(1)
        s = rng.randint(0, 2**32, 8, dtype=np.uint32)
        m = rng.randint(0, 2**32, 8, dtype=np.uint32)
        out_jnp = np.asarray(fvr_hash.absorb8(jnp.asarray(s), jnp.asarray(m)))
        out_py = ref._absorb8([int(x) for x in s], [int(x) for x in m])
        assert [int(x) for x in out_jnp] == out_py

    def test_batched_matches_rowwise(self):
        rng = np.random.RandomState(2)
        s = rng.randint(0, 2**32, (5, 8), dtype=np.uint32)
        m = rng.randint(0, 2**32, (5, 8), dtype=np.uint32)
        batched = np.asarray(fvr_hash.absorb8(jnp.asarray(s), jnp.asarray(m)))
        for i in range(5):
            row = np.asarray(fvr_hash.absorb8(jnp.asarray(s[i]), jnp.asarray(m[i])))
            assert (batched[i] == row).all()

    def test_not_identity(self):
        z = jnp.zeros(8, jnp.uint32)
        out = np.asarray(fvr_hash.absorb8(z, z))
        assert not (out == 0).all()

    def test_sensitive_to_single_bit(self):
        s = jnp.asarray(np.arange(8, dtype=np.uint32))
        m0 = jnp.zeros(8, jnp.uint32)
        m1 = m0.at[3].set(1)
        a = np.asarray(fvr_hash.absorb8(s, m0))
        b = np.asarray(fvr_hash.absorb8(s, m1))
        assert (a != b).any()

    def test_lane_diffusion(self):
        """A flip in one lane must affect a *different* lane (roll diffusion)."""
        s = jnp.zeros(8, jnp.uint32)
        m0 = jnp.zeros(8, jnp.uint32)
        m1 = m0.at[4].set(0x80000000)
        a = np.asarray(fvr_hash.absorb8(s, m0))
        b = np.asarray(fvr_hash.absorb8(s, m1))
        changed = {i for i in range(8) if a[i] != b[i]}
        assert changed - {4}, f"only lane 4 changed: {changed}"

    def test_rotl_wraps(self):
        x = jnp.asarray(np.uint32(0x80000001))
        assert int(fvr_hash.rotl(x, 1)) == 0x00000003

    @given(st.lists(st.integers(0, 2**32 - 1), min_size=16, max_size=16))
    @settings(max_examples=50, deadline=None)
    def test_hypothesis_jnp_vs_python(self, words):
        s, m = words[:8], words[8:]
        out_jnp = np.asarray(fvr_hash.absorb8(
            jnp.asarray(np.array(s, np.uint32)), jnp.asarray(np.array(m, np.uint32))))
        assert [int(x) for x in out_jnp] == ref._absorb8(s, m)


# ---------------------------------------------------------------------------
# Pallas kernel vs jnp reference
# ---------------------------------------------------------------------------

class TestBlockDigests:
    @pytest.mark.parametrize("num_blocks", [1, 2, 4, 16])
    @pytest.mark.parametrize("wpb", [8, 64, 4096])
    def test_kernel_matches_ref(self, num_blocks, wpb):
        chunk = rand_chunk(np.random.RandomState(num_blocks * wpb), num_blocks, wpb)
        k = np.asarray(fvr_hash.block_digests(jnp.asarray(chunk), words_per_block=wpb))
        r = np.asarray(ref.block_digests_ref(jnp.asarray(chunk), words_per_block=wpb))
        assert (k == r).all()

    def test_kernel_matches_python_block(self):
        wpb = 64
        chunk = rand_chunk(np.random.RandomState(7), 2, wpb)
        k = np.asarray(fvr_hash.block_digests(jnp.asarray(chunk), words_per_block=wpb))
        py = ref.PyFvr256(2, wpb)
        for b in range(2):
            expect = py.block_digest([int(x) for x in chunk[b]])
            assert [int(x) for x in k[b]] == expect

    def test_blocks_independent(self):
        """Changing block j must not change digest of block i != j."""
        wpb = 64
        chunk = rand_chunk(np.random.RandomState(9), 4, wpb)
        base = np.asarray(fvr_hash.block_digests(jnp.asarray(chunk), words_per_block=wpb))
        chunk2 = chunk.copy()
        chunk2[2, 10] ^= 0xFF
        out = np.asarray(fvr_hash.block_digests(jnp.asarray(chunk2), words_per_block=wpb))
        assert (out[2] != base[2]).any()
        for i in (0, 1, 3):
            assert (out[i] == base[i]).all()

    def test_deterministic(self):
        chunk = rand_chunk(np.random.RandomState(3), 4, 64)
        a = np.asarray(fvr_hash.block_digests(jnp.asarray(chunk), words_per_block=64))
        b = np.asarray(fvr_hash.block_digests(jnp.asarray(chunk), words_per_block=64))
        assert (a == b).all()

    def test_rejects_bad_width(self):
        with pytest.raises(ValueError):
            fvr_hash.block_digests(jnp.zeros((2, 64), jnp.uint32), words_per_block=32)

    def test_rejects_non_multiple_of_lanes(self):
        with pytest.raises(ValueError):
            fvr_hash.block_digests(jnp.zeros((2, 12), jnp.uint32), words_per_block=12)

    @given(st.integers(0, 3), st.integers(1, 4), st.randoms(use_true_random=False))
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_shapes(self, log_blocks, groups, rnd):
        num_blocks, wpb = 2 ** log_blocks, 8 * groups
        rng = np.random.RandomState(rnd.randrange(2**31))
        chunk = rand_chunk(rng, num_blocks, wpb)
        k = np.asarray(fvr_hash.block_digests(jnp.asarray(chunk), words_per_block=wpb))
        r = np.asarray(ref.block_digests_ref(jnp.asarray(chunk), words_per_block=wpb))
        assert k.shape == (num_blocks, LANES) and (k == r).all()


# ---------------------------------------------------------------------------
# tree combine + finalize
# ---------------------------------------------------------------------------

class TestTreeCombine:
    def test_matches_python(self):
        rng = np.random.RandomState(11)
        d = rng.randint(0, 2**32, (8, 8), dtype=np.uint32)
        out = np.asarray(fvr_hash.tree_combine(jnp.asarray(d)))
        digests = [[int(x) for x in row] for row in d]
        while len(digests) > 1:
            digests = [ref._absorb8(digests[i], digests[i + 1])
                       for i in range(0, len(digests), 2)]
        assert [int(x) for x in out] == digests[0]

    def test_single_block_passthrough(self):
        d = np.arange(8, dtype=np.uint32).reshape(1, 8)
        out = np.asarray(fvr_hash.tree_combine(jnp.asarray(d)))
        assert (out == d[0]).all()

    def test_rejects_non_power_of_two(self):
        with pytest.raises(ValueError):
            fvr_hash.tree_combine(jnp.zeros((3, 8), jnp.uint32))

    def test_order_sensitive(self):
        rng = np.random.RandomState(13)
        d = rng.randint(0, 2**32, (4, 8), dtype=np.uint32)
        a = np.asarray(fvr_hash.tree_combine(jnp.asarray(d)))
        b = np.asarray(fvr_hash.tree_combine(jnp.asarray(d[::-1].copy())))
        assert (a != b).any()


class TestFinalize:
    def test_length_sensitive(self):
        root = jnp.asarray(np.arange(8, dtype=np.uint32))
        a = np.asarray(fvr_hash.finalize_chunk(root, jnp.uint32(100), jnp.uint32(0), 4, 64))
        b = np.asarray(fvr_hash.finalize_chunk(root, jnp.uint32(101), jnp.uint32(0), 4, 64))
        assert (a != b).any()

    def test_index_sensitive(self):
        root = jnp.asarray(np.arange(8, dtype=np.uint32))
        a = np.asarray(fvr_hash.finalize_chunk(root, jnp.uint32(100), jnp.uint32(0), 4, 64))
        b = np.asarray(fvr_hash.finalize_chunk(root, jnp.uint32(100), jnp.uint32(1), 4, 64))
        assert (a != b).any()

    def test_geometry_sensitive(self):
        root = jnp.asarray(np.arange(8, dtype=np.uint32))
        a = np.asarray(fvr_hash.finalize_chunk(root, jnp.uint32(100), jnp.uint32(0), 4, 64))
        b = np.asarray(fvr_hash.finalize_chunk(root, jnp.uint32(100), jnp.uint32(0), 8, 32))
        assert (a != b).any()


# ---------------------------------------------------------------------------
# streaming python implementation
# ---------------------------------------------------------------------------

class TestPyFvr256:
    GEOM = dict(num_blocks=2, words_per_block=8)  # 64-byte chunks: fast

    def test_empty(self):
        h = ref.PyFvr256(**self.GEOM)
        assert len(h.hexdigest()) == 64

    def test_update_split_invariance(self):
        data = bytes(range(256)) * 3
        whole = ref.PyFvr256(**self.GEOM)
        whole.update(data)
        parts = ref.PyFvr256(**self.GEOM)
        for i in range(0, len(data), 7):
            parts.update(data[i:i + 7])
        assert whole.hexdigest() == parts.hexdigest()

    def test_length_extension_distinct(self):
        a = ref.fvr256_hex(b"\x00" * 64, **self.GEOM)
        b = ref.fvr256_hex(b"\x00" * 65, **self.GEOM)
        assert a != b

    def test_single_bit_avalanche(self):
        base = bytearray(range(200))
        a = ref.fvr256_hex(bytes(base), **self.GEOM)
        base[100] ^= 1
        b = ref.fvr256_hex(bytes(base), **self.GEOM)
        diff = sum(bin(int(a[i:i+8], 16) ^ int(b[i:i+8], 16)).count("1")
                   for i in range(0, 64, 8))
        assert diff > 64, f"weak avalanche: {diff}/256 bits flipped"

    def test_rejects_non_power_of_two_blocks(self):
        with pytest.raises(ValueError):
            ref.PyFvr256(num_blocks=3)

    @given(st.binary(max_size=300), st.integers(1, 20))
    @settings(max_examples=40, deadline=None)
    def test_hypothesis_split_invariance(self, data, split):
        whole = ref.PyFvr256(**self.GEOM)
        whole.update(data)
        parts = ref.PyFvr256(**self.GEOM)
        for i in range(0, len(data), split):
            parts.update(data[i:i + split])
        assert whole.hexdigest() == parts.hexdigest()

    @given(st.binary(min_size=1, max_size=200), st.integers(0, 199))
    @settings(max_examples=40, deadline=None)
    def test_hypothesis_bitflip_changes_digest(self, data, pos):
        pos = pos % len(data)
        mutated = bytearray(data)
        mutated[pos] ^= 0x01
        assert ref.fvr256_hex(data, **self.GEOM) != \
            ref.fvr256_hex(bytes(mutated), **self.GEOM)

    def test_geometry_changes_digest(self):
        data = bytes(range(128))
        assert ref.fvr256_hex(data, 2, 8) != ref.fvr256_hex(data, 4, 8)
