"""L2 correctness: the chunk-digest graph, variants, and AOT lowering."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

SMALL = model.Variant("small", num_blocks=2, words_per_block=8)  # 64 B chunks


def digest_bytes(data: bytes, variant, chunk_index=0, use_pallas=True):
    """Pad-to-chunk + run the L2 graph, as the Rust runtime will."""
    padded = data + b"\x00" * (variant.chunk_bytes - len(data))
    words = jnp.asarray(np.frombuffer(padded, dtype="<u4"))
    out = model.chunk_digest(
        words,
        jnp.array([len(data)], jnp.uint32),
        jnp.array([chunk_index], jnp.uint32),
        variant=variant, use_pallas=use_pallas,
    )
    return [int(x) for x in np.asarray(out[0])]


class TestChunkDigest:
    def test_matches_python_spec(self):
        data = bytes(range(64))
        got = digest_bytes(data, SMALL)
        expect = ref.PyFvr256(2, 8).chunk_digest(data, 0)
        assert got == expect

    def test_partial_chunk_matches_python(self):
        data = b"fiver" * 3
        got = digest_bytes(data, SMALL)
        expect = ref.PyFvr256(2, 8).chunk_digest(data, 0)
        assert got == expect

    def test_pallas_and_ref_paths_agree(self):
        data = os.urandom(64)
        assert digest_bytes(data, SMALL, use_pallas=True) == \
            digest_bytes(data, SMALL, use_pallas=False)

    def test_chunk_index_matters(self):
        data = os.urandom(64)
        assert digest_bytes(data, SMALL, chunk_index=0) != \
            digest_bytes(data, SMALL, chunk_index=1)

    def test_padding_not_colliding(self):
        """'abc' and 'abc\\0' share padded words but differ in true length."""
        assert digest_bytes(b"abc", SMALL) != digest_bytes(b"abc\x00", SMALL)

    def test_output_shape_dtype(self):
        v = SMALL
        words = jnp.zeros((v.chunk_words,), jnp.uint32)
        out = model.chunk_digest(words, jnp.array([0], jnp.uint32),
                                 jnp.array([0], jnp.uint32), variant=v)
        assert out[0].shape == (8,) and out[0].dtype == jnp.uint32

    @given(st.binary(min_size=0, max_size=64), st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_matches_python(self, data, idx):
        got = digest_bytes(data, SMALL, chunk_index=idx)
        expect = ref.PyFvr256(2, 8).chunk_digest(data, idx)
        assert got == expect


class TestVariants:
    def test_registry_geometries(self):
        assert model.VARIANTS["256k"].chunk_bytes == 256 * 1024
        assert model.VARIANTS["1m"].chunk_bytes == 1024 * 1024
        assert model.VARIANTS["4m"].chunk_bytes == 4 * 1024 * 1024

    @pytest.mark.parametrize("name", list(model.VARIANTS))
    def test_power_of_two_blocks(self, name):
        b = model.VARIANTS[name].num_blocks
        assert b & (b - 1) == 0

    def test_variant_chunks_give_distinct_digests(self):
        """Geometry is bound into the digest: same bytes, different variant."""
        data = os.urandom(64)
        a = digest_bytes(data, SMALL)
        b = digest_bytes(data, model.Variant("s2", 4, 8))
        assert a != b


class TestLowering:
    def test_lower_small_variant(self):
        lowered = model.lower_variant(SMALL)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "u32[16]" in text

    def test_hlo_has_three_params_and_tuple_result(self):
        text = aot.to_hlo_text(model.lower_variant(SMALL))
        assert "parameter(0)" in text
        assert "parameter(1)" in text
        assert "parameter(2)" in text
        assert "(u32[8]" in text  # tuple-wrapped result

    def test_lowering_deterministic(self):
        a = aot.to_hlo_text(model.lower_variant(SMALL))
        b = aot.to_hlo_text(model.lower_variant(SMALL))
        assert a == b


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="run `make artifacts` first")
class TestArtifacts:
    def test_manifest_lists_all_variants(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            manifest = json.load(f)
        names = {v["name"] for v in manifest["variants"]}
        assert names == set(model.VARIANTS)
        for v in manifest["variants"]:
            assert os.path.exists(os.path.join(ART_DIR, v["artifact"]))
            assert os.path.exists(os.path.join(ART_DIR, v["artifact_ref"]))

    def test_artifact_is_hlo_text(self):
        with open(os.path.join(ART_DIR, "fvr_hash_256k.hlo.txt")) as f:
            head = f.read(4096)
        assert "HloModule" in head

    def test_test_vectors_well_formed(self):
        with open(os.path.join(ART_DIR, "test_vectors.json")) as f:
            vectors = json.load(f)
        assert len(vectors["streams"]) >= 30
        for c in vectors["streams"]:
            assert len(c["hex"]) == 64
        for c in vectors["chunks"]:
            assert len(c["digest_words"]) == 8

    def test_vectors_match_pyfvr(self):
        """Re-derive a sample of the emitted vectors."""
        with open(os.path.join(ART_DIR, "test_vectors.json")) as f:
            vectors = json.load(f)
        for c in vectors["streams"][:6]:
            if c["pattern"] == "zeros":
                data = bytes(c["length"])
                assert ref.fvr256_hex(data, c["num_blocks"], c["words_per_block"]) == c["hex"]
