"""L2 lowering structure: the AOT artifacts must stay runnable by the
xla_extension-0.5.1 text parser and keep the calling convention the Rust
runtime hard-codes (see rust/src/runtime/engine.rs)."""

import re

import pytest

from compile import aot, model

SMALL = model.Variant("small", num_blocks=2, words_per_block=8)


@pytest.fixture(scope="module")
def hlo_text():
    return aot.to_hlo_text(model.lower_variant(SMALL))


class TestHloStructure:
    def test_single_module(self, hlo_text):
        assert hlo_text.count("HloModule") == 1

    def test_entry_signature(self, hlo_text):
        # Three params, tuple result of one u32[8] (return_tuple=True).
        entry = hlo_text[hlo_text.index("ENTRY"):]
        assert "parameter(0)" in entry
        assert "parameter(1)" in entry
        assert "parameter(2)" in entry
        assert re.search(r"ROOT .*tuple", entry), "tuple-wrapped result"

    def test_no_custom_calls(self, hlo_text):
        # interpret=True must lower Pallas to plain HLO; a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        assert "custom-call" not in hlo_text

    def test_no_host_roundtrips(self, hlo_text):
        # The whole chunk digest is one fused module: no infeed/outfeed,
        # no send/recv.
        for op in ("infeed", "outfeed", "send(", "recv("):
            assert op not in hlo_text, op

    def test_kernel_loop_present(self, hlo_text):
        # The fori_loop over word groups lowers to an HLO while: the L1
        # kernel rides inside this module rather than being unrolled
        # (keeps artifact size O(1) in block size — the pallas artifact is
        # ~25x smaller than the unrolled jnp reference lowering).
        assert "while" in hlo_text

    def test_u32_only_arithmetic(self, hlo_text):
        # The hash is pure u32 ARX; floating point appearing here would
        # mean an accidental dtype promotion in the kernel.
        assert "f32[" not in hlo_text
        assert "f64[" not in hlo_text

    def test_text_parseable_sizes(self):
        # Variant geometry scales the artifact sub-linearly (loops, not
        # unrolling): lowering the real 256k variant stays small.
        text = aot.to_hlo_text(model.lower_variant(model.VARIANTS["256k"]))
        assert len(text) < 1 << 20, "artifact should stay well under 1 MiB of text"


class TestVectorGeneration:
    def test_lcg_matches_spec(self):
        # The LCG in aot.py is mirrored by rust/src/util/rng.rs::Lcg31.
        from compile.aot import emit_test_vectors  # noqa: F401 (import check)
        s = 0x12345678
        out = []
        for _ in range(4):
            s = (s * 1103515245 + 12345) & 0x7FFFFFFF
            out.append(s & 0xFF)
        assert out[0] == ((0x12345678 * 1103515245 + 12345) & 0x7FFFFFFF) & 0xFF
