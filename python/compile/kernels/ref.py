"""Pure-jnp / pure-python oracle for FVR-256 — the CORE correctness signal.

Two independent re-implementations of the spec in fvr_hash.py:

  * ``block_digests_ref`` / ``chunk_digest_ref`` — pure jnp, no Pallas.
    pytest asserts bit-identity against the Pallas kernel.
  * ``PyFvr256`` — plain-python streaming implementation over ``bytes``
    (no jax at all). Used to generate artifacts/test_vectors.json, which the
    Rust port (rust/src/hashes/fvr256.rs) must reproduce bit-for-bit.
"""

from __future__ import annotations

import struct

import jax.numpy as jnp

from .fvr_hash import (C0, IV, LANES, M1, M2, MAGIC_F, MAGIC_R, absorb8,
                       finalize_chunk, iv_vector, tree_combine)

MASK = 0xFFFFFFFF


def block_digests_ref(chunk: jnp.ndarray, *, words_per_block: int = 4096) -> jnp.ndarray:
    """(B, W) u32 -> (B, 8) u32 block digests, no Pallas.

    Folds absorb8 over the (B, W/8, 8) group view with batched ops: every
    block advances in lockstep, state shaped (B, 8).
    """
    num_blocks, w = chunk.shape
    if w != words_per_block or w % LANES:
        raise ValueError("bad chunk geometry")
    groups = chunk.astype(jnp.uint32).reshape(num_blocks, w // LANES, LANES)
    state = jnp.broadcast_to(iv_vector(), (num_blocks, LANES))
    for g in range(w // LANES):
        state = absorb8(state, groups[:, g, :])
    return state


def chunk_digest_ref(chunk: jnp.ndarray, length_bytes, chunk_index, *,
                     words_per_block: int = 4096) -> jnp.ndarray:
    """Full reference pipeline: block digests -> tree combine -> finalize."""
    d = block_digests_ref(chunk, words_per_block=words_per_block)
    root = tree_combine(d)
    return finalize_chunk(root, jnp.uint32(length_bytes), jnp.uint32(chunk_index),
                          chunk.shape[0], words_per_block)


# ---------------------------------------------------------------------------
# Plain-python streaming implementation (no jax) — the normative byte-level
# behaviour the Rust port matches. Mirrors rust/src/hashes/fvr256.rs.
# ---------------------------------------------------------------------------

def _rotl(x: int, k: int) -> int:
    x &= MASK
    return ((x << k) | (x >> (32 - k))) & MASK


def _absorb8(state: list[int], m: list[int]) -> list[int]:
    s = [((a + int(C0)) & MASK) ^ _rotl(b, 9) for a, b in zip(state, m)]
    s = [(x * int(M1)) & MASK for x in s]
    s = [_rotl(x, 13) for x in s]
    rolled = s[1:] + s[:1]  # roll(-1): lane i sees lane i+1
    s = [(x + _rotl(r, 7)) & MASK for x, r in zip(s, rolled)]
    s = [(x * int(M2)) & MASK for x in s]
    s = [(x ^ (x >> 16)) & MASK for x in s]
    return s


class PyFvr256:
    """Streaming FVR-256 over bytes: chunk -> blocks -> tree -> chain.

    Chunking/chaining layout (mirrored by runtime::FvrHasher in Rust):
      * the stream is cut into chunks of ``chunk_bytes`` (= B*W*4);
      * a final partial chunk is zero-padded to full size, its digest
        finalized with the *true* byte length;
      * file digest = fold absorb8 over chunk digests starting from IV,
        then absorb8 with [total_lo, total_hi, nchunks, MAGIC_F, MAGIC_R,
        0, 0, 0].
    """

    def __init__(self, num_blocks: int = 64, words_per_block: int = 4096):
        if num_blocks & (num_blocks - 1):
            raise ValueError("num_blocks must be a power of two")
        self.num_blocks = num_blocks
        self.words_per_block = words_per_block
        self.chunk_bytes = num_blocks * words_per_block * 4
        self._buf = bytearray()
        self._state = list(IV)
        self._chunk_index = 0
        self._total = 0

    # -- chunk-level primitives (usable standalone for cross-checks) --------

    def block_digest(self, words: list[int]) -> list[int]:
        assert len(words) == self.words_per_block
        state = list(IV)
        for g in range(0, len(words), LANES):
            state = _absorb8(state, words[g:g + LANES])
        return state

    def chunk_digest(self, data: bytes, chunk_index: int) -> list[int]:
        """Digest one (possibly short) chunk. data is zero-padded to size."""
        true_len = len(data)
        assert true_len <= self.chunk_bytes
        padded = data + b"\x00" * (self.chunk_bytes - true_len)
        words = list(struct.unpack(f"<{len(padded) // 4}I", padded))
        w = self.words_per_block
        digests = [self.block_digest(words[i * w:(i + 1) * w])
                   for i in range(self.num_blocks)]
        while len(digests) > 1:
            digests = [_absorb8(digests[i], digests[i + 1])
                       for i in range(0, len(digests), 2)]
        meta = [true_len & MASK, chunk_index & MASK, MAGIC_F, MAGIC_R,
                self.num_blocks, self.words_per_block, 0, 0]
        return _absorb8(digests[0], meta)

    # -- streaming interface -------------------------------------------------

    def update(self, data: bytes) -> None:
        self._buf.extend(data)
        self._total += len(data)
        while len(self._buf) >= self.chunk_bytes:
            chunk = bytes(self._buf[:self.chunk_bytes])
            del self._buf[:self.chunk_bytes]
            self._absorb_chunk(chunk)

    def _absorb_chunk(self, chunk: bytes) -> None:
        cd = self.chunk_digest(chunk, self._chunk_index)
        self._state = _absorb8(self._state, cd)
        self._chunk_index += 1

    def digest_words(self) -> list[int]:
        if self._buf:
            self._absorb_chunk(bytes(self._buf))
            self._buf.clear()
        meta = [self._total & MASK, (self._total >> 32) & MASK,
                self._chunk_index & MASK, MAGIC_F, MAGIC_R, 0, 0, 0]
        return _absorb8(self._state, meta)

    def hexdigest(self) -> str:
        return "".join(f"{w:08x}" for w in self.digest_words())


def fvr256_hex(data: bytes, num_blocks: int = 64, words_per_block: int = 4096) -> str:
    h = PyFvr256(num_blocks, words_per_block)
    h.update(data)
    return h.hexdigest()
