"""Layer-1 Pallas kernel: FVR-256 block-parallel hash.

The paper's compute hot-spot is checksum computation (sequential MD5/SHA at
~3 Gbps/core — slower than the 40/100 Gbps links it verifies). MD5's serial
dependency chain has no TPU parallelism, so per DESIGN.md
§Hardware-Adaptation we restructure the insight the paper cites from fsum
[32]: split the stream into independent blocks, hash blocks in parallel
lanes, and tree-combine the block digests.

FVR-256 specification (normative — the Rust port in rust/src/hashes/fvr256.rs
must match bit-for-bit; cross-language vectors live in
artifacts/test_vectors.json):

  * Words are u32, packed little-endian from the byte stream.
  * A *block* is W words (default W=4096, i.e. 16 KiB).
  * A *chunk* is B blocks, hashed independently then tree-combined.
  * State is 8 u32 words, initialised to IV (the SHA-256 IV constants).
  * absorb8(state, m): the one round function, used everywhere —
        s  = (state + C0) XOR rotl(m, 9)   (asymmetric in state vs message:
                                            swapping siblings in the combine
                                            tree must change the root; C0
                                            also kills the all-zero fixed
                                            point)
        s  = s * M1                     (wrapping)
        s  = rotl(s, 13)
        s  = s + rotl(roll(s, -1), 7)   (lane diffusion; roll along the
                                         8-lane axis, wrapping add)
        s  = s * M2
        s  = s XOR (s >> 16)
    All element-wise over the 8 lanes -> maps directly onto the VPU.
  * block_digest(block) = fold absorb8 over the W/8 groups of 8 words,
    starting from IV.
  * tree_combine(d[0..B]) = pairwise absorb8(d[2i], d[2i+1]) until one row
    remains (B must be a power of two).
  * chunk_digest = absorb8(root, [len_bytes, chunk_index, MAGIC_F, MAGIC_R,
    B, W, 0, 0]) — the true (pre-padding) byte length and position bind the
    digest to content, length and order.

Pallas structure: grid over blocks; BlockSpec stages one (1, W) block per
grid step into VMEM (16 KiB ≪ VMEM budget); the state vector lives in
registers across a fori_loop over the W/8 groups. The IV is threaded in as a
broadcast operand because Pallas kernels may not capture constants.
interpret=True always — the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated structurally in DESIGN.md §10.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# ARX constants: murmur3/xxhash-style odd multipliers (invertible mod 2^32)
# and the SHA-256 IV for the initial state. Kept as numpy scalars so they
# inline as jaxpr literals instead of captured constants (a Pallas
# requirement).
M1 = np.uint32(0x9E3779B1)
M2 = np.uint32(0x85EBCA77)
C0 = np.uint32(0x7F4A7C15)  # round constant: breaks zero fixed point + symmetry
MAGIC_F = 0x46495645  # "FIVE"
MAGIC_R = 0x52C3D2E1  # "R" + tail of SHA-1 h4

IV = (
    0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
    0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19,
)

LANES = 8  # state width in u32 words


def rotl(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Rotate-left each u32 lane by a static k."""
    x = x.astype(jnp.uint32)
    return (x << np.uint32(k)) | (x >> np.uint32(32 - k))


def absorb8(state: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """The FVR-256 round function. state, m: (..., 8) u32 -> (..., 8) u32.

    Element-wise over lanes except one neighbour-lane rotation (roll by -1
    along the last axis) that diffuses across the state vector. Asymmetric
    in (state, m) so sibling order in the combine tree is detectable.
    """
    s = (state.astype(jnp.uint32) + C0) ^ rotl(m, 9)
    s = s * M1
    s = rotl(s, 13)
    s = s + rotl(jnp.roll(s, -1, axis=-1), 7)
    s = s * M2
    s = s ^ (s >> np.uint32(16))
    return s


def iv_vector() -> jnp.ndarray:
    return jnp.array(IV, dtype=jnp.uint32)


def _block_kernel(iv_ref, x_ref, o_ref, *, words_per_block: int):
    """Pallas body: digest one (1, W) block staged into VMEM.

    The W-word block is viewed as (W/8, 8) groups; a fori_loop folds absorb8
    over groups with the 8-lane state carried in registers.
    """
    groups = words_per_block // LANES
    block = x_ref[...].reshape(groups, LANES)

    def body(i, state):
        return absorb8(state, block[i])

    state = jax.lax.fori_loop(0, groups, body, iv_ref[...].reshape(LANES))
    o_ref[...] = state.reshape(1, LANES)


@functools.partial(jax.jit, static_argnames=("words_per_block",))
def block_digests(chunk: jnp.ndarray, *, words_per_block: int = 4096) -> jnp.ndarray:
    """Hash a (B, W) u32 chunk into (B, 8) u32 block digests via Pallas.

    Grid = (B,): one grid step per block, one block resident in VMEM at a
    time. The IV rides along as a (1, 8) operand mapped to every grid step.
    interpret=True (see module docstring).
    """
    num_blocks, w = chunk.shape
    if w != words_per_block:
        raise ValueError(f"chunk width {w} != words_per_block {words_per_block}")
    if w % LANES != 0:
        raise ValueError(f"words_per_block {w} must be a multiple of {LANES}")
    iv = iv_vector().reshape(1, LANES)
    return pl.pallas_call(
        functools.partial(_block_kernel, words_per_block=words_per_block),
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, LANES), lambda i: (0, 0)),
            pl.BlockSpec((1, words_per_block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks, LANES), jnp.uint32),
        interpret=True,
    )(iv, chunk.astype(jnp.uint32))


def tree_combine(digests: jnp.ndarray) -> jnp.ndarray:
    """Pairwise-reduce (B, 8) block digests to a single (8,) root digest.

    B must be a power of two; the loop unrolls at trace time (log2 B levels,
    each level fully data-parallel).
    """
    d = digests.astype(jnp.uint32)
    b = d.shape[0]
    if b & (b - 1):
        raise ValueError(f"block count {b} must be a power of two")
    while d.shape[0] > 1:
        d = absorb8(d[0::2], d[1::2])
    return d[0]


def finalize_chunk(root: jnp.ndarray, length_bytes: jnp.ndarray,
                   chunk_index: jnp.ndarray, num_blocks: int,
                   words_per_block: int) -> jnp.ndarray:
    """Bind the root digest to true byte length, chunk position and geometry."""
    meta = jnp.stack([
        jnp.asarray(length_bytes, jnp.uint32).reshape(()),
        jnp.asarray(chunk_index, jnp.uint32).reshape(()),
        jnp.uint32(MAGIC_F),
        jnp.uint32(MAGIC_R),
        jnp.uint32(num_blocks),
        jnp.uint32(words_per_block),
        jnp.uint32(0),
        jnp.uint32(0),
    ])
    return absorb8(root, meta)
