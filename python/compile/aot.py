"""AOT compile path: lower the L2 chunk-digest graph to HLO text artifacts.

Run once at build time (``make artifacts``); Python never executes on the
request path. Emits, per variant in model.VARIANTS:

    artifacts/fvr_hash_<name>.hlo.txt        Pallas-kernel pipeline
    artifacts/fvr_hash_<name>_ref.hlo.txt    pure-jnp reference pipeline
    artifacts/manifest.json                  geometry + calling convention
    artifacts/test_vectors.json              cross-language vectors for Rust

Interchange format is HLO **text**, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects with ``proto.id() <= INT_MAX``.
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import PyFvr256, fvr256_hex


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit_variant(out_dir: str, variant: model.Variant) -> dict:
    entry = {
        "name": variant.name,
        "num_blocks": variant.num_blocks,
        "words_per_block": variant.words_per_block,
        "chunk_bytes": variant.chunk_bytes,
        "params": ["u32[chunk_words]", "u32[1] length_bytes", "u32[1] chunk_index"],
        "result": "tuple(u32[8])",
    }
    for use_pallas, suffix in ((True, ""), (False, "_ref")):
        text = to_hlo_text(model.lower_variant(variant, use_pallas=use_pallas))
        fname = f"fvr_hash_{variant.name}{suffix}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry["artifact" if use_pallas else "artifact_ref"] = fname
        print(f"  wrote {fname} ({len(text)} chars)")
    return entry


def emit_test_vectors(out_dir: str) -> None:
    """Deterministic byte patterns -> FVR-256 digests, for the Rust port.

    Patterns cover: empty, single byte, sub-word, exact word, exact block,
    exact chunk, chunk+1, multi-chunk, and an LCG pseudo-random stream —
    the boundary cases where a port most plausibly diverges.
    """
    def lcg_bytes(n: int, seed: int = 0x12345678) -> bytes:
        out = bytearray()
        s = seed
        for _ in range(n):
            s = (s * 1103515245 + 12345) & 0x7FFFFFFF
            out.append(s & 0xFF)
        return bytes(out)

    geometries = [(16, 4096), (64, 4096)]
    cases = []
    for nb, wpb in geometries:
        chunk_bytes = nb * wpb * 4
        lengths = [0, 1, 3, 4, 64, wpb * 4, chunk_bytes,
                   chunk_bytes + 1, chunk_bytes * 2 + 17]
        for ln in lengths:
            for pattern, data in (("zeros", bytes(ln)),
                                  ("lcg", lcg_bytes(ln))):
                cases.append({
                    "num_blocks": nb,
                    "words_per_block": wpb,
                    "pattern": pattern,
                    "length": ln,
                    "hex": fvr256_hex(data, nb, wpb),
                })
    # Also pin raw chunk digests (pre-chain) so runtime::FvrHasher's artifact
    # output can be checked in isolation.
    chunk_cases = []
    for nb, wpb in geometries:
        h = PyFvr256(nb, wpb)
        for ln in (0, 5, wpb * 4, nb * wpb * 4):
            data = lcg_bytes(ln, seed=ln + 1)
            chunk_cases.append({
                "num_blocks": nb,
                "words_per_block": wpb,
                "length": ln,
                "chunk_index": 3,
                "seed": ln + 1,
                "digest_words": h.chunk_digest(data, 3),
            })
    with open(os.path.join(out_dir, "test_vectors.json"), "w") as f:
        json.dump({"streams": cases, "chunks": chunk_cases}, f, indent=1)
    print(f"  wrote test_vectors.json ({len(cases)} streams, {len(chunk_cases)} chunks)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--variants", default=",".join(model.VARIANTS),
                    help="comma-separated variant names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "hash": "FVR-256", "variants": []}
    for name in args.variants.split(","):
        print(f"lowering variant {name} ...")
        manifest["variants"].append(emit_variant(args.out_dir, model.VARIANTS[name]))
    emit_test_vectors(args.out_dir)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("  wrote manifest.json")


if __name__ == "__main__":
    main()
