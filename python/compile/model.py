"""Layer-2 JAX model: the FVR-256 chunk-digest compute graph.

The paper's "model" is the integrity-verification compute path: a chunk of
the byte stream in, a 256-bit digest out. The graph calls the Layer-1 Pallas
kernel (block digests) and tree-combines + finalizes in plain jnp so the
whole thing lowers into ONE fused HLO module per chunk-size variant.

Variants (see VARIANTS) are fixed-shape: AOT lowering bakes (B, W) in, the
Rust runtime picks the artifact matching its configured chunk size and zero-
pads the final partial chunk (the true length is an input, so padding cannot
collide).

Inputs (per the artifact calling convention, relied on by rust/src/runtime):
  param 0: u32[B*W]  chunk words, little-endian packed
  param 1: u32[1]    true byte length of the chunk (pre-padding)
  param 2: u32[1]    chunk index within the stream
Output: 1-tuple of u32[8] (lowered with return_tuple=True).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import fvr_hash
from .kernels.fvr_hash import LANES


@dataclass(frozen=True)
class Variant:
    """A fixed-geometry lowering of the chunk digest graph."""
    name: str
    num_blocks: int        # B — power of two
    words_per_block: int   # W — multiple of 8

    @property
    def chunk_bytes(self) -> int:
        return self.num_blocks * self.words_per_block * 4

    @property
    def chunk_words(self) -> int:
        return self.num_blocks * self.words_per_block


# 16 KiB blocks (one VMEM-resident block per grid step) at every size.
VARIANTS = {
    "256k": Variant("256k", num_blocks=16, words_per_block=4096),
    "1m": Variant("1m", num_blocks=64, words_per_block=4096),
    "4m": Variant("4m", num_blocks=256, words_per_block=4096),
}


def chunk_digest(chunk_words: jnp.ndarray, length_bytes: jnp.ndarray,
                 chunk_index: jnp.ndarray, *, variant: Variant,
                 use_pallas: bool = True):
    """u32[B*W], u32[1], u32[1] -> (u32[8],): the full digest pipeline.

    use_pallas=False swaps in the pure-jnp reference block hash — lowered as
    a separate artifact (``*_ref``) for runtime A/B testing of the kernel.
    """
    v = variant
    grid = chunk_words.astype(jnp.uint32).reshape(v.num_blocks, v.words_per_block)
    if use_pallas:
        digests = fvr_hash.block_digests(grid, words_per_block=v.words_per_block)
    else:
        from .kernels import ref
        digests = ref.block_digests_ref(grid, words_per_block=v.words_per_block)
    root = fvr_hash.tree_combine(digests)
    final = fvr_hash.finalize_chunk(root, length_bytes[0], chunk_index[0],
                                    v.num_blocks, v.words_per_block)
    return (final,)


def lower_variant(variant: Variant, *, use_pallas: bool = True):
    """jax.jit().lower() the chunk digest graph at this variant's geometry."""
    fn = functools.partial(chunk_digest, variant=variant, use_pallas=use_pallas)
    chunk_spec = jax.ShapeDtypeStruct((variant.chunk_words,), jnp.uint32)
    scalar_spec = jax.ShapeDtypeStruct((1,), jnp.uint32)
    return jax.jit(fn).lower(chunk_spec, scalar_spec, scalar_spec)
